//! Metrics: throughput counters, per-shard group-commit counters,
//! latency histograms, energy accounting and plain-text report
//! rendering for the coordinator and benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::coordinator::batcher::SealReason;
use crate::util::stats::LatencyHistogram;

/// Lock-free counters shared across coordinator workers.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub requests_coalesced: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub rows_updated: AtomicU64,
    pub shift_cycles: AtomicU64,
    pub reconfigs: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            requests_submitted: Self::get(&self.requests_submitted),
            requests_completed: Self::get(&self.requests_completed),
            requests_rejected: Self::get(&self.requests_rejected),
            requests_coalesced: Self::get(&self.requests_coalesced),
            batches_flushed: Self::get(&self.batches_flushed),
            rows_updated: Self::get(&self.rows_updated),
            shift_cycles: Self::get(&self.shift_cycles),
            reconfigs: Self::get(&self.reconfigs),
        }
    }
}

/// Plain-data snapshot of the counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub requests_rejected: u64,
    pub requests_coalesced: u64,
    pub batches_flushed: u64,
    pub rows_updated: u64,
    pub shift_cycles: u64,
    pub reconfigs: u64,
}

impl CounterSnapshot {
    /// Mean rows per flushed batch — the coordinator's key efficiency
    /// figure (FAST amortizes one q-cycle batch over many rows).
    pub fn rows_per_batch(&self) -> f64 {
        if self.batches_flushed == 0 {
            return 0.0;
        }
        self.rows_updated as f64 / self.batches_flushed as f64
    }
}

/// Per-shard counters for the sharded update engine: group-commit seal
/// reasons, coalescing effectiveness, and queue pressure. One instance
/// per shard, written by that shard's worker (and, for the queue gauge,
/// by producers), read by anyone via [`ShardCounters::snapshot`].
#[derive(Debug, Default)]
pub struct ShardCounters {
    /// Requests admitted to this shard's queue.
    pub requests: AtomicU64,
    /// Batches sealed (== sum of the four seal-reason counters).
    pub batches_sealed: AtomicU64,
    /// Batches sealed because the size threshold was reached.
    pub sealed_full: AtomicU64,
    /// Batches sealed because a different batch kind arrived.
    pub sealed_kind_change: AtomicU64,
    /// Batches sealed by the group-commit deadline.
    pub sealed_deadline: AtomicU64,
    /// Batches sealed by an explicit flush / read / write / shutdown.
    pub sealed_forced: AtomicU64,
    /// Requests absorbed into an already-touched row (coalesce hits).
    pub coalesce_hits: AtomicU64,
    /// Rows carried by this shard's sealed batches.
    pub rows_updated: AtomicU64,
    /// Requests admitted but not yet drained by the worker (gauge).
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub queue_high_water: AtomicU64,
    /// Last commit sequence number this shard applied (gauge; seqs
    /// start at 1, 0 = nothing committed yet).
    pub commit_seq: AtomicU64,
    /// Completion tickets resolved by this shard's worker.
    pub tickets_resolved: AtomicU64,
    /// In-array queries this shard's worker answered.
    pub queries: AtomicU64,
    /// Spin-loop probes blocking submits burned while this shard's
    /// ring was full (admission contention, cheap path).
    pub submit_spins: AtomicU64,
    /// Times a blocking submit exhausted its spin budget and parked
    /// on the shard's eventcount (admission contention, slow path).
    pub park_events: AtomicU64,
    /// Ticket waiters woken per seal (a count histogram riding the
    /// latency-recorder machinery: "ns" fields hold waiter counts).
    /// One sample per seal that resolved at least one ticket — the
    /// mean is the batch-wake amortization factor.
    pub wake_batch: LatencyRecorder,
    /// Wall-clock query execution latency (one sample per query).
    pub query_wall: LatencyRecorder,
    /// Submit→ticket-resolve latency, wall-clock (one sample per
    /// resolved ticket).
    pub commit_wall: LatencyRecorder,
    /// Modeled macro latency of the committing batch, one sample per
    /// resolved ticket (the modeled analogue of `commit_wall`).
    pub commit_modeled: LatencyRecorder,
    /// WAL records appended by this shard's appender (batch commits +
    /// conventional-port writes). Zero when durability is off.
    pub wal_records: AtomicU64,
    /// WAL bytes appended (frames, headers excluded).
    pub wal_bytes: AtomicU64,
    /// fsyncs issued (group-commit coalesced — compare against
    /// `wal_records` to see the amortization).
    pub wal_fsyncs: AtomicU64,
    /// Segment rotations performed.
    pub wal_rotations: AtomicU64,
    /// fsync call latency histogram (one sample per fsync).
    pub wal_fsync: LatencyRecorder,
    /// `write_all` calls that delivered ≥ 2 coalesced WAL frames
    /// (cross-seal write coalescing; zero when durability is off or
    /// the fsync policy is `always`).
    pub wal_coalesced_writes: AtomicU64,
    /// Frames delivered by those coalesced writes (compare against
    /// `wal_records` for the coalescing ratio).
    pub wal_coalesced_frames: AtomicU64,
    /// Monotonic stamp (`telemetry::now_ns`) of this shard's last
    /// completed fsync; 0 until one happens. A gauge for span tracing
    /// (`t_fsync`), deliberately not part of [`ShardSnapshot`].
    pub last_fsync_ns: AtomicU64,
}

impl ShardCounters {
    /// Record one sealed batch: the reason plus its row/request load.
    pub fn note_sealed(&self, reason: SealReason, rows_touched: u64, requests: u64) {
        Counters::inc(&self.batches_sealed, 1);
        let bucket = match reason {
            SealReason::Full => &self.sealed_full,
            SealReason::KindChange => &self.sealed_kind_change,
            SealReason::Deadline => &self.sealed_deadline,
            SealReason::Forced => &self.sealed_forced,
        };
        Counters::inc(bucket, 1);
        Counters::inc(&self.rows_updated, rows_touched);
        Counters::inc(&self.coalesce_hits, requests.saturating_sub(rows_touched));
    }

    pub fn snapshot(&self) -> ShardSnapshot {
        ShardSnapshot {
            requests: Counters::get(&self.requests),
            batches_sealed: Counters::get(&self.batches_sealed),
            sealed_full: Counters::get(&self.sealed_full),
            sealed_kind_change: Counters::get(&self.sealed_kind_change),
            sealed_deadline: Counters::get(&self.sealed_deadline),
            sealed_forced: Counters::get(&self.sealed_forced),
            coalesce_hits: Counters::get(&self.coalesce_hits),
            rows_updated: Counters::get(&self.rows_updated),
            queue_depth: Counters::get(&self.queue_depth),
            queue_high_water: Counters::get(&self.queue_high_water),
            commit_seq: Counters::get(&self.commit_seq),
            tickets_resolved: Counters::get(&self.tickets_resolved),
            queries: Counters::get(&self.queries),
            submit_spins: Counters::get(&self.submit_spins),
            park_events: Counters::get(&self.park_events),
            wake_batch: self.wake_batch.summary(),
            query_wall: self.query_wall.summary(),
            commit_wall: self.commit_wall.summary(),
            commit_modeled: self.commit_modeled.summary(),
            wal_records: Counters::get(&self.wal_records),
            wal_bytes: Counters::get(&self.wal_bytes),
            wal_fsyncs: Counters::get(&self.wal_fsyncs),
            wal_rotations: Counters::get(&self.wal_rotations),
            wal_fsync: self.wal_fsync.summary(),
            wal_coalesced_writes: Counters::get(&self.wal_coalesced_writes),
            wal_coalesced_frames: Counters::get(&self.wal_coalesced_frames),
        }
    }
}

/// Plain-data snapshot of one shard's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardSnapshot {
    pub requests: u64,
    pub batches_sealed: u64,
    pub sealed_full: u64,
    pub sealed_kind_change: u64,
    pub sealed_deadline: u64,
    pub sealed_forced: u64,
    pub coalesce_hits: u64,
    pub rows_updated: u64,
    pub queue_depth: u64,
    pub queue_high_water: u64,
    pub commit_seq: u64,
    pub tickets_resolved: u64,
    /// In-array queries answered by this shard.
    pub queries: u64,
    /// Spin probes burned by blocking submits while the ring was full.
    pub submit_spins: u64,
    /// Blocking submits that parked after exhausting the spin budget.
    pub park_events: u64,
    /// Waiters woken per seal (count histogram: "ns" = waiter counts).
    pub wake_batch: LatencySummary,
    /// Query execution wall-clock latency (p50/p95/p99).
    pub query_wall: LatencySummary,
    /// Submit→ticket-resolve wall-clock latency (p50/p95/p99).
    pub commit_wall: LatencySummary,
    /// Modeled commit latency distribution (p50/p95/p99).
    pub commit_modeled: LatencySummary,
    /// WAL records appended (0 when durability is off).
    pub wal_records: u64,
    /// WAL bytes appended.
    pub wal_bytes: u64,
    /// fsyncs issued (coalesced per the fsync policy).
    pub wal_fsyncs: u64,
    /// Segment rotations.
    pub wal_rotations: u64,
    /// fsync latency histogram (p50/p95/p99).
    pub wal_fsync: LatencySummary,
    /// `write_all` calls carrying ≥ 2 coalesced WAL frames.
    pub wal_coalesced_writes: u64,
    /// Frames delivered by those coalesced writes.
    pub wal_coalesced_frames: u64,
}

/// Modeled energy accumulator (fJ) — fed from `energy::Cost` values.
#[derive(Debug, Default)]
pub struct EnergyAccount {
    total_fj: AtomicU64, // stored as millis of fJ for atomic adds
}

impl EnergyAccount {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_fj(&self, fj: f64) {
        debug_assert!(fj >= 0.0);
        self.total_fj
            .fetch_add((fj * 1000.0).round() as u64, Ordering::Relaxed);
    }

    pub fn total_fj(&self) -> f64 {
        self.total_fj.load(Ordering::Relaxed) as f64 / 1000.0
    }

    pub fn total_pj(&self) -> f64 {
        self.total_fj() / 1000.0
    }
}

/// Wall-clock stopwatch with a latency histogram.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    hist: std::sync::Mutex<LatencyHistogram>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_ns(t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn record_ns(&self, ns: u64) {
        self.hist.lock().expect("recorder poisoned").record(ns);
    }

    pub fn summary(&self) -> LatencySummary {
        let h = self.hist.lock().expect("recorder poisoned");
        LatencySummary {
            count: h.count(),
            mean_ns: h.mean_ns(),
            p50_ns: h.percentile_ns(50.0),
            p95_ns: h.percentile_ns(95.0),
            p99_ns: h.percentile_ns(99.0),
            max_ns: h.max_ns(),
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

/// Render a two-column report table (used by the CLI and benches).
pub fn render_table(title: &str, rows: &[(String, String)]) -> String {
    let key_w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0).max(8);
    let val_w = rows.iter().map(|(_, v)| v.len()).max().unwrap_or(0).max(8);
    let mut out = String::new();
    out.push_str(&format!("┌─ {title} {}┐\n", "─".repeat((key_w + val_w + 5).saturating_sub(title.len() + 3))));
    for (k, v) in rows {
        out.push_str(&format!("│ {k:<key_w$} │ {v:>val_w$} │\n"));
    }
    out.push_str(&format!("└{}┘\n", "─".repeat(key_w + val_w + 6)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_roundtrip() {
        let c = Counters::new();
        Counters::inc(&c.requests_submitted, 5);
        Counters::inc(&c.batches_flushed, 2);
        Counters::inc(&c.rows_updated, 200);
        let s = c.snapshot();
        assert_eq!(s.requests_submitted, 5);
        assert_eq!(s.rows_per_batch(), 100.0);
    }

    #[test]
    fn shard_counters_bucket_seal_reasons() {
        let s = ShardCounters::default();
        s.note_sealed(SealReason::Full, 10, 14);
        s.note_sealed(SealReason::Deadline, 1, 1);
        s.note_sealed(SealReason::KindChange, 2, 2);
        s.note_sealed(SealReason::Forced, 3, 5);
        let snap = s.snapshot();
        assert_eq!(snap.batches_sealed, 4);
        assert_eq!(
            snap.sealed_full + snap.sealed_kind_change + snap.sealed_deadline + snap.sealed_forced,
            snap.batches_sealed
        );
        assert_eq!(snap.sealed_deadline, 1);
        assert_eq!(snap.rows_updated, 16);
        assert_eq!(snap.coalesce_hits, 4 + 2);
    }

    #[test]
    fn rows_per_batch_empty_is_zero() {
        assert_eq!(CounterSnapshot::default().rows_per_batch(), 0.0);
    }

    #[test]
    fn shard_commit_histograms_snapshot() {
        let s = ShardCounters::default();
        s.commit_wall.record_ns(1_000);
        s.commit_wall.record_ns(2_000);
        s.commit_modeled.record_ns(20);
        s.commit_seq.store(7, Ordering::Relaxed);
        Counters::inc(&s.tickets_resolved, 2);
        Counters::inc(&s.queries, 3);
        s.query_wall.record_ns(400);
        let snap = s.snapshot();
        assert_eq!(snap.commit_seq, 7);
        assert_eq!(snap.tickets_resolved, 2);
        assert_eq!(snap.queries, 3);
        assert_eq!(snap.query_wall.count, 1);
        assert_eq!(snap.commit_wall.count, 2);
        assert!(snap.commit_wall.p50_ns >= 1_000);
        assert!(snap.commit_wall.p95_ns >= snap.commit_wall.p50_ns);
        assert!(snap.commit_wall.p99_ns >= snap.commit_wall.p95_ns);
        assert_eq!(snap.commit_modeled.count, 1);
    }

    #[test]
    fn shard_wal_counters_snapshot() {
        let s = ShardCounters::default();
        Counters::inc(&s.wal_records, 3);
        Counters::inc(&s.wal_bytes, 120);
        Counters::inc(&s.wal_fsyncs, 1);
        s.wal_fsync.record_ns(5_000);
        let snap = s.snapshot();
        assert_eq!(snap.wal_records, 3);
        assert_eq!(snap.wal_bytes, 120);
        assert_eq!(snap.wal_fsyncs, 1);
        assert_eq!(snap.wal_rotations, 0);
        assert_eq!(snap.wal_fsync.count, 1);
    }

    #[test]
    fn energy_account_accumulates() {
        let e = EnergyAccount::new();
        e.add_fj(380.0);
        e.add_fj(0.5);
        assert!((e.total_fj() - 380.5).abs() < 1e-9);
        assert!((e.total_pj() - 0.3805).abs() < 1e-9);
    }

    #[test]
    fn latency_recorder_times_closures() {
        let r = LatencyRecorder::new();
        let v = r.time(|| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            42
        });
        assert_eq!(v, 42);
        let s = r.summary();
        assert_eq!(s.count, 1);
        assert!(s.mean_ns >= 1_000_000.0);
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "demo",
            &[("alpha".into(), "1".into()), ("beta".into(), "22".into())],
        );
        assert!(t.contains("alpha"));
        assert!(t.contains("22"));
        assert!(t.lines().count() >= 4);
    }
}
