//! VGG-7-shaped parallel weight-update application — the paper's
//! headline evaluation (Section III: "the weight update task in an
//! 8-bit quantized VGG-7 framework", 4.4× energy efficiency and 96.0×
//! speed over the fully-digital memory-computing-separated baseline).
//!
//! The model: every weight lives in one FAST row (8-bit quantized),
//! the seven VGG-7 weight tensors (plus the classifier head) striped
//! proportionally across the logical row space. A training step
//! produces one signed, quantized gradient delta per weight; all of
//! them land as coalesced add/sub requests through the sharded
//! [`crate::coordinator::UpdateEngine`] and commit as fully-concurrent
//! FAST batch ops at the step's flush barrier — q shift cycles for the
//! whole row space, versus the digital baseline's row-by-row
//! read→ALU→write sweep. That asymmetry *is* the paper's claim, and
//! here it is asserted programmatically: the experiment driver
//! [`crate::experiments::weight_update`] replays the same recorded
//! trace on every backend and reports the modeled speed /
//! energy-efficiency ratios (repo bars: ≥ 50× speed, ≥ 3× energy at
//! 128×8; paper anchors 96.0× / 4.4× — their baseline also pays
//! instruction and data-movement overheads our digital model
//! charitably omits).
//!
//! The workload is generated as a [`Trace`] (see [`record_trace`]), so
//! the exact same stream replays bit-identically on every backend,
//! fidelity tier and shard count — the trainer is both the paper's
//! missing workload and the reference user of the trace substrate.

use anyhow::ensure;

use crate::util::bits;
use crate::util::rng::{splitmix64, Rng};
use crate::Result;

use super::trace::{BackendKind, Trace, TraceEvent};
use crate::coordinator::UpdateRequest;

/// Paper anchor: modeled speedup of FAST over the digital baseline on
/// the VGG-7 8-bit weight-update task.
pub const PAPER_SPEEDUP_X: f64 = 96.0;
/// Paper anchor: energy-efficiency ratio on the same task.
pub const PAPER_ENERGY_EFF_X: f64 = 4.4;
/// Repo acceptance bar asserted by `fast train` (conservative vs the
/// paper anchor — see the module docs).
pub const MIN_SPEEDUP_X: f64 = 50.0;
/// Repo acceptance bar for the energy-efficiency ratio.
pub const MIN_ENERGY_EFF_X: f64 = 3.0;

/// One weight tensor of the network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    pub name: &'static str,
    /// True parameter count of the tensor (used for proportional
    /// striping; the row space is a scale model of the network).
    pub weights: u64,
}

/// The VGG-7 weight tensors (CIFAR-shaped: 2×128C3, 2×256C3, 2×512C3,
/// 1024-unit FC, 10-way head — the configuration 8-bit training papers
/// call "VGG-7").
pub const VGG7: [LayerSpec; 8] = [
    LayerSpec { name: "conv1-128", weights: 3 * 3 * 3 * 128 },
    LayerSpec { name: "conv2-128", weights: 3 * 3 * 128 * 128 },
    LayerSpec { name: "conv3-256", weights: 3 * 3 * 128 * 256 },
    LayerSpec { name: "conv4-256", weights: 3 * 3 * 256 * 256 },
    LayerSpec { name: "conv5-512", weights: 3 * 3 * 256 * 512 },
    LayerSpec { name: "conv6-512", weights: 3 * 3 * 512 * 512 },
    LayerSpec { name: "fc1-1024", weights: 512 * 4 * 4 * 1024 },
    LayerSpec { name: "fc2-10", weights: 1024 * 10 },
];

/// A layer's slice of the logical row space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSlice {
    pub name: &'static str,
    /// First logical row of the slice.
    pub start: usize,
    /// Rows owned by the layer (≥ 1).
    pub rows: usize,
}

/// Stripe the layer tensors across `rows` rows proportionally to their
/// parameter counts (largest-remainder apportionment; every layer gets
/// at least one row; the slices tile the row space exactly).
pub fn stripe(layers: &[LayerSpec], rows: usize) -> Vec<LayerSlice> {
    assert!(!layers.is_empty() && rows >= layers.len(), "need >= 1 row per layer");
    let total: u64 = layers.iter().map(|l| l.weights).sum();
    assert!(total > 0);
    let mut alloc: Vec<usize> = Vec::with_capacity(layers.len());
    let mut remainders: Vec<(usize, f64)> = Vec::with_capacity(layers.len());
    for (i, l) in layers.iter().enumerate() {
        let exact = rows as f64 * l.weights as f64 / total as f64;
        let floor = exact.floor() as usize;
        alloc.push(floor.max(1));
        remainders.push((i, exact - floor as f64));
    }
    let mut allocated: usize = alloc.iter().sum();
    // Hand surplus rows to the largest fractional remainders…
    remainders.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut ri = 0;
    while allocated < rows {
        alloc[remainders[ri % remainders.len()].0] += 1;
        allocated += 1;
        ri += 1;
    }
    // …or reclaim over-allocation (min-1 clamps on tiny row spaces)
    // from the largest slices.
    while allocated > rows {
        let (imax, _) = alloc
            .iter()
            .enumerate()
            .max_by_key(|&(_, &a)| a)
            .expect("non-empty");
        assert!(alloc[imax] > 1, "rows < layers was rejected above");
        alloc[imax] -= 1;
        allocated -= 1;
    }
    let mut out = Vec::with_capacity(layers.len());
    let mut start = 0;
    for (l, a) in layers.iter().zip(alloc) {
        out.push(LayerSlice { name: l.name, start, rows: a });
        start += a;
    }
    debug_assert_eq!(start, rows);
    out
}

/// Trainer workload shape. All fields deterministic — two configs that
/// compare equal generate byte-identical traces.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainerConfig {
    /// Logical rows (one 8-bit weight per row), striped over the layers.
    pub rows: usize,
    /// Weight/delta quantization width (the paper's task: 8).
    pub q: usize,
    pub epochs: usize,
    /// Minibatch steps per epoch; each step updates every layer and
    /// ends in a flush barrier (one fully-concurrent batch per shard).
    pub steps_per_epoch: usize,
    /// Worker shards for the engine (power of two dividing `rows`).
    pub shards: usize,
    /// Seed for weight init and the per-(epoch, step, layer) gradient
    /// streams.
    pub seed: u64,
    /// Fraction of each layer's weights updated per step (1.0 = dense
    /// gradients; < 1.0 models sparse/top-k updates).
    pub density: f64,
}

impl TrainerConfig {
    /// The paper-shaped default: 8-bit weights, dense gradients, two
    /// epochs of four steps.
    pub fn vgg7(rows: usize, q: usize) -> Self {
        TrainerConfig {
            rows,
            q,
            epochs: 2,
            steps_per_epoch: 4,
            shards: 1,
            seed: 0x766_7,
            density: 1.0,
        }
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.rows >= VGG7.len(), "need >= {} rows (one per layer)", VGG7.len());
        ensure!((1..=32).contains(&self.q), "q must be in 1..=32");
        ensure!(self.epochs >= 1 && self.steps_per_epoch >= 1, "epochs/steps must be >= 1");
        ensure!(
            self.shards >= 1 && self.shards.is_power_of_two() && self.rows % self.shards == 0,
            "shards must be a power of two dividing rows"
        );
        ensure!(
            self.density > 0.0 && self.density <= 1.0,
            "density must be in (0, 1], got {}",
            self.density
        );
        Ok(())
    }
}

/// Independent gradient stream per (seed, epoch, step, layer) — the
/// trace is insensitive to layer iteration order refactors.
fn layer_stream_seed(seed: u64, epoch: usize, step: usize, layer: usize) -> u64 {
    let mut s = seed ^ ((epoch as u64) << 42) ^ ((step as u64) << 21) ^ (layer as u64 + 1);
    splitmix64(&mut s)
}

/// Generate the deterministic VGG-7 weight-update trace for a config:
/// seeded 8-bit weight init (conventional-port writes), then per step
/// a signed quantized gradient delta for every scheduled weight of
/// every layer, closed by a flush barrier.
pub fn record_trace(cfg: &TrainerConfig) -> Result<Trace> {
    cfg.validate()?;
    let layout = stripe(&VGG7, cfg.rows);
    let mut trace = Trace::new(format!("vgg7-{}x{}", cfg.rows, cfg.q), cfg.rows, cfg.q, cfg.seed);
    let mut init = Rng::new(cfg.seed);
    for row in 0..cfg.rows {
        trace.push_write(row, init.below(bits::mask(cfg.q) as u64 + 1) as u32);
    }
    for epoch in 0..cfg.epochs {
        for step in 0..cfg.steps_per_epoch {
            for (li, slice) in layout.iter().enumerate() {
                let mut g = Rng::new(layer_stream_seed(cfg.seed, epoch, step, li));
                for row in slice.start..slice.start + slice.rows {
                    if cfg.density < 1.0 && !g.chance(cfg.density) {
                        continue;
                    }
                    // Non-zero magnitude: a zero delta is the batch
                    // identity and would model no work.
                    let mag = 1 + g.below(bits::mask(cfg.q) as u64) as u32;
                    let req = if g.chance(0.5) {
                        UpdateRequest::sub(row, mag)
                    } else {
                        UpdateRequest::add(row, mag)
                    };
                    trace.push_update(req);
                }
            }
            trace.push_flush();
        }
    }
    Ok(trace)
}

/// Result of training on one backend.
#[derive(Debug, Clone)]
pub struct TrainRun {
    pub backend: &'static str,
    pub rows: usize,
    pub q: usize,
    pub epochs: usize,
    pub steps_per_epoch: usize,
    /// Update requests applied (after coalescing accounting).
    pub updates: u64,
    pub batches: u64,
    pub rows_per_batch: f64,
    /// Modeled macro time for the whole run (ns).
    pub modeled_ns: f64,
    /// Modeled macro energy for the whole run (pJ).
    pub modeled_pj: f64,
    /// Host wall-clock of the replay (µs).
    pub wall_us: f64,
    /// Completion tickets the ticketed replay waited on (one per shard
    /// per training step; all resolved or the run errored).
    pub tickets: u64,
    /// Per-shard submit→commit wall-clock latency over the run's steps
    /// — the per-step latency the ticket refactor makes measurable.
    pub commit_wall: Vec<crate::metrics::LatencySummary>,
    /// Final weight state (for cross-backend bit-identity checks).
    pub final_state: Vec<u32>,
}

impl TrainRun {
    pub fn ns_per_epoch(&self) -> f64 {
        self.modeled_ns / self.epochs as f64
    }

    pub fn pj_per_epoch(&self) -> f64 {
        self.modeled_pj / self.epochs as f64
    }
}

/// Replay an already-recorded trainer trace on one backend. The
/// config must describe the trace it claims to (shape and step
/// schedule), since the per-epoch cost figures divide by it.
pub fn run_trace(cfg: &TrainerConfig, trace: &Trace, kind: BackendKind) -> Result<TrainRun> {
    cfg.validate()?;
    ensure!(
        trace.rows == cfg.rows && trace.q == cfg.q,
        "trace shape {}x{} != config shape {}x{}",
        trace.rows,
        trace.q,
        cfg.rows,
        cfg.q
    );
    let flushes = trace
        .events
        .iter()
        .filter(|e| matches!(e, TraceEvent::Flush))
        .count();
    ensure!(
        flushes == cfg.epochs * cfg.steps_per_epoch,
        "trace has {flushes} step barriers but the config claims {} epochs x {} steps",
        cfg.epochs,
        cfg.steps_per_epoch
    );
    let report = trace.replay_on(kind, cfg.shards)?;
    Ok(TrainRun {
        backend: report.stats.backend,
        rows: cfg.rows,
        q: cfg.q,
        epochs: cfg.epochs,
        steps_per_epoch: cfg.steps_per_epoch,
        updates: report.stats.completed,
        batches: report.stats.batches,
        rows_per_batch: report.stats.rows_per_batch,
        modeled_ns: report.stats.modeled_ns,
        modeled_pj: report.stats.modeled_energy_pj,
        wall_us: report.wall_us,
        tickets: report.tickets_waited,
        commit_wall: report.stats.shards.iter().map(|s| s.commit_wall).collect(),
        final_state: report.final_state,
    })
}

/// Record the config's trace and train on one backend. (The
/// cross-backend comparison with the paper-anchored ratio bars lives
/// in [`crate::experiments::weight_update`].)
pub fn run(cfg: &TrainerConfig, kind: BackendKind) -> Result<TrainRun> {
    let trace = record_trace(cfg)?;
    run_trace(cfg, &trace, kind)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastmem::Fidelity;

    #[test]
    fn stripe_tiles_the_row_space_exactly() {
        for rows in [8usize, 100, 128, 1024] {
            let slices = stripe(&VGG7, rows);
            assert_eq!(slices.len(), VGG7.len());
            let mut next = 0;
            for s in &slices {
                assert_eq!(s.start, next, "slices must tile contiguously");
                assert!(s.rows >= 1, "every layer gets >= 1 row");
                next += s.rows;
            }
            assert_eq!(next, rows, "slices must cover all rows");
        }
    }

    #[test]
    fn stripe_is_proportional() {
        let slices = stripe(&VGG7, 1024);
        let fc1 = slices.iter().find(|s| s.name == "fc1-1024").unwrap();
        let conv1 = slices.iter().find(|s| s.name == "conv1-128").unwrap();
        // fc1 holds ~65% of VGG-7's parameters; conv1 a rounding error.
        assert!(fc1.rows > 500, "fc1 rows = {}", fc1.rows);
        assert!(conv1.rows <= 4, "conv1 rows = {}", conv1.rows);
    }

    #[test]
    fn record_trace_is_deterministic_and_dense() {
        let cfg = TrainerConfig::vgg7(64, 8);
        let a = record_trace(&cfg).unwrap();
        let b = record_trace(&cfg).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        // Dense gradients: every step updates every row once.
        assert_eq!(a.updates(), 64 * cfg.epochs * cfg.steps_per_epoch);
        // One flush barrier per step.
        let flushes = a
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Flush))
            .count();
        assert_eq!(flushes, cfg.epochs * cfg.steps_per_epoch);
    }

    #[test]
    fn sparse_density_thins_the_stream() {
        let mut cfg = TrainerConfig::vgg7(128, 8);
        cfg.density = 0.25;
        let t = record_trace(&cfg).unwrap();
        let dense = 128 * cfg.epochs * cfg.steps_per_epoch;
        assert!(t.updates() < dense / 2, "{} of {dense}", t.updates());
        assert!(t.updates() > dense / 16);
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let mut cfg = TrainerConfig::vgg7(4, 8); // fewer rows than layers
        assert!(record_trace(&cfg).is_err());
        cfg = TrainerConfig::vgg7(128, 8);
        cfg.shards = 3; // not a power of two
        assert!(run(&cfg, BackendKind::Digital).is_err());
        cfg = TrainerConfig::vgg7(128, 8);
        cfg.density = 0.0;
        assert!(record_trace(&cfg).is_err());
        cfg = TrainerConfig::vgg7(128, 8);
        cfg.q = 33;
        assert!(record_trace(&cfg).is_err());
    }

    #[test]
    fn run_trace_rejects_configs_that_misdescribe_the_trace() {
        let cfg = TrainerConfig::vgg7(64, 8);
        let trace = record_trace(&cfg).unwrap();
        let mut wrong_epochs = cfg.clone();
        wrong_epochs.epochs += 1; // per-epoch figures would be skewed
        assert!(run_trace(&wrong_epochs, &trace, BackendKind::Digital).is_err());
        let mut wrong_rows = cfg.clone();
        wrong_rows.rows = 128;
        assert!(run_trace(&wrong_rows, &trace, BackendKind::Digital).is_err());
        assert!(run_trace(&cfg, &trace, BackendKind::Digital).is_ok());
    }

    #[test]
    fn fast_and_digital_agree_on_state_and_diverge_on_cost() {
        // The paper-anchored ratio bars themselves are asserted in
        // experiments::weight_update (one implementation, one test).
        let mut cfg = TrainerConfig::vgg7(128, 8);
        cfg.epochs = 1;
        cfg.steps_per_epoch = 2;
        let trace = record_trace(&cfg).unwrap();
        let fast = run_trace(&cfg, &trace, BackendKind::Fast(Fidelity::WordFast)).unwrap();
        let digital = run_trace(&cfg, &trace, BackendKind::Digital).unwrap();
        assert_eq!(fast.final_state, digital.final_state);
        assert_eq!(fast.final_state, trace.reference_state());
        assert_eq!(fast.updates, digital.updates);
        assert!(fast.batches >= 1);
        assert!(digital.modeled_ns > fast.modeled_ns);
        assert!(digital.modeled_pj > fast.modeled_pj);
    }

    #[test]
    fn bitplane_backend_trains_identically_with_identical_energy() {
        let mut cfg = TrainerConfig::vgg7(128, 8);
        cfg.epochs = 1;
        let trace = record_trace(&cfg).unwrap();
        let word = run_trace(&cfg, &trace, BackendKind::Fast(Fidelity::WordFast)).unwrap();
        let plane = run_trace(&cfg, &trace, BackendKind::BitPlane).unwrap();
        assert_eq!(word.final_state, plane.final_state);
        assert_eq!(word.modeled_pj, plane.modeled_pj, "tier must not move energy");
        assert_eq!(word.modeled_ns, plane.modeled_ns);
    }

    #[test]
    fn sharding_preserves_state_and_energy_on_dense_traces() {
        let mut base = TrainerConfig::vgg7(128, 8);
        base.epochs = 1;
        let trace = record_trace(&base).unwrap();
        let one = run_trace(&base, &trace, BackendKind::Fast(Fidelity::WordFast)).unwrap();
        for shards in [2usize, 4] {
            let mut cfg = base.clone();
            cfg.shards = shards;
            let sharded = run_trace(&cfg, &trace, BackendKind::Fast(Fidelity::WordFast)).unwrap();
            assert_eq!(sharded.final_state, one.final_state, "shards = {shards}");
            // Ticketed replay: one ack per shard per step, and the
            // per-shard commit histograms saw every step.
            let steps = (cfg.epochs * cfg.steps_per_epoch) as u64;
            assert_eq!(sharded.tickets, steps * shards as u64, "shards = {shards}");
            assert_eq!(sharded.commit_wall.len(), shards);
            assert!(sharded.commit_wall.iter().all(|s| s.count == steps));
            // Dense flush groups touch every shard, so the per-bank
            // energy accounting sums to the same total.
            assert!(
                (sharded.modeled_pj - one.modeled_pj).abs() < 1e-9,
                "shards = {shards}: {} vs {} pJ",
                sharded.modeled_pj,
                one.modeled_pj
            );
        }
    }
}
