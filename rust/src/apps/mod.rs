//! Application workloads motivating the paper (Section I) plus the
//! headline evaluation task (Section III):
//!
//! - [`table`] — database-style delta-update key/counter table
//! - [`graph`] — CSR graph with row-parallel feature propagation
//! - [`histogram`] — high-concurrency streaming counters
//! - [`trainer`] — the VGG-7-shaped 8-bit parallel weight-update task
//!   (the paper's 96.0× / 4.4× comparison, asserted programmatically)
//! - [`trace`] — deterministic workload traces: record an update
//!   stream once, replay it bit-identically onto any backend /
//!   fidelity tier / shard configuration

pub mod graph;
pub mod histogram;
pub mod table;
pub mod trace;
pub mod trainer;

pub use graph::{reference_round, CsrGraph, GraphEngine};
pub use histogram::Histogram;
pub use table::DeltaTable;
pub use trace::{state_digest, BackendKind, ReplayReport, Trace, TraceEvent};
pub use trainer::{LayerSlice, LayerSpec, TrainRun, TrainerConfig, VGG7};
