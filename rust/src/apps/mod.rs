//! Application workloads motivating the paper (Section I):
//!
//! - [`table`] — database-style delta-update key/counter table
//! - [`graph`] — CSR graph with row-parallel feature propagation
//! - [`histogram`] — high-concurrency streaming counters

pub mod graph;
pub mod histogram;
pub mod table;

pub use graph::{reference_round, CsrGraph, GraphEngine};
pub use histogram::Histogram;
pub use table::DeltaTable;
