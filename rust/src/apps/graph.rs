//! Graph feature updates — the paper's graph-computing motivation
//! ("the parallel feature update in graph computing", refs [7][8]).
//!
//! A CSR graph whose per-node integer features live in FAST rows. One
//! propagation round sends each node's contribution to its neighbours;
//! the coordinator coalesces all messages per destination into one
//! dense delta vector, so the whole round lands as O(1) fully-
//! concurrent batch ops instead of |E| row-by-row read-modify-writes.

use anyhow::ensure;

use crate::coordinator::{UpdateEngine, UpdateRequest};
use crate::util::bits;
use crate::util::rng::Rng;
use crate::Result;

/// Compressed-sparse-row directed graph.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    /// offsets[n]..offsets[n+1] indexes `targets` for node n's out-edges.
    pub offsets: Vec<usize>,
    pub targets: Vec<usize>,
}

impl CsrGraph {
    pub fn nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn edges(&self) -> usize {
        self.targets.len()
    }

    pub fn out_neighbors(&self, n: usize) -> &[usize] {
        &self.targets[self.offsets[n]..self.offsets[n + 1]]
    }

    /// Build from an edge list.
    pub fn from_edges(nodes: usize, edges: &[(usize, usize)]) -> Self {
        let mut deg = vec![0usize; nodes];
        for &(s, t) in edges {
            assert!(s < nodes && t < nodes, "edge ({s},{t}) out of range");
            deg[s] += 1;
        }
        let mut offsets = Vec::with_capacity(nodes + 1);
        offsets.push(0);
        for n in 0..nodes {
            offsets.push(offsets[n] + deg[n]);
        }
        let mut fill = offsets.clone();
        let mut targets = vec![0usize; edges.len()];
        for &(s, t) in edges {
            targets[fill[s]] = t;
            fill[s] += 1;
        }
        CsrGraph { offsets, targets }
    }

    /// Random graph: `nodes` nodes, ~`avg_degree` out-edges per node.
    pub fn random(nodes: usize, avg_degree: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut edges = Vec::with_capacity(nodes * avg_degree);
        for s in 0..nodes {
            for _ in 0..avg_degree {
                let t = rng.below(nodes as u64) as usize;
                edges.push((s, t));
            }
        }
        Self::from_edges(nodes, &edges)
    }

    /// A ring + chords graph (deterministic, connected).
    pub fn ring_with_chords(nodes: usize, chord_stride: usize) -> Self {
        let mut edges = Vec::with_capacity(nodes * 2);
        for n in 0..nodes {
            edges.push((n, (n + 1) % nodes));
            if chord_stride > 1 {
                edges.push((n, (n + chord_stride) % nodes));
            }
        }
        Self::from_edges(nodes, &edges)
    }
}

/// Graph engine: features in FAST rows, propagation via batch updates.
pub struct GraphEngine {
    graph: CsrGraph,
    engine: UpdateEngine,
    q: usize,
}

impl GraphEngine {
    /// The engine must have at least `graph.nodes()` rows.
    pub fn new(graph: CsrGraph, engine: UpdateEngine) -> Result<Self> {
        ensure!(
            engine.config().rows >= graph.nodes(),
            "engine rows {} < graph nodes {}",
            engine.config().rows,
            graph.nodes()
        );
        let q = engine.config().q;
        Ok(GraphEngine { graph, engine, q })
    }

    pub fn graph(&self) -> &CsrGraph {
        &self.graph
    }

    /// Initialize node features.
    pub fn set_features(&mut self, feats: &[u32]) -> Result<()> {
        ensure!(feats.len() == self.graph.nodes(), "feature count mismatch");
        for (n, &f) in feats.iter().enumerate() {
            self.engine.write(n, f)?;
        }
        Ok(())
    }

    pub fn features(&mut self) -> Result<Vec<u32>> {
        let snap = self.engine.snapshot()?;
        Ok(snap[..self.graph.nodes()].to_vec())
    }

    /// One propagation round: every node n sends `msg(feature[n])` to
    /// each out-neighbour; destinations accumulate mod 2^q. Message
    /// generation reads a consistent snapshot (synchronous/Jacobi
    /// semantics, as in GCN-style feature aggregation).
    pub fn propagate_round(&mut self, msg: impl Fn(u32) -> u32) -> Result<()> {
        let feats = self.features()?;
        // Bulk-submit per round: one channel crossing per chunk instead
        // of per edge (§Perf: ~3× on message-heavy graphs).
        let mut reqs = Vec::with_capacity(self.graph.edges());
        for (n, &f) in feats.iter().enumerate() {
            let m = msg(f) & bits::mask(self.q);
            if m == 0 {
                continue;
            }
            for &t in self.graph.out_neighbors(n) {
                reqs.push(UpdateRequest::add(t, m));
            }
        }
        let mut tickets = Vec::new();
        for chunk in reqs.chunks(8192) {
            tickets.extend(self.engine.submit_many_ticketed(chunk.to_vec())?);
        }
        // Commit the round (explicit barrier, built from per-shard
        // drains), then wait for every chunk's commit ack.
        self.engine.drain_all()?;
        for t in tickets {
            t.wait()?;
        }
        Ok(())
    }

    /// Run `rounds` of degree-normalized-ish accumulate: each node sends
    /// feature >> shift (integer attenuation) to neighbours.
    pub fn run(&mut self, rounds: usize, attenuation_shift: u32) -> Result<()> {
        for _ in 0..rounds {
            self.propagate_round(|f| f >> attenuation_shift)?;
        }
        Ok(())
    }

    pub fn stats(&self) -> crate::coordinator::EngineStats {
        self.engine.stats()
    }

    pub fn close(self) -> Result<()> {
        self.engine.shutdown()
    }
}

/// Reference implementation of `propagate_round` over plain vectors —
/// the oracle the engine-backed version is tested against.
pub fn reference_round(
    graph: &CsrGraph,
    feats: &[u32],
    q: usize,
    msg: impl Fn(u32) -> u32,
) -> Vec<u32> {
    let mut out = feats.to_vec();
    for (n, &f) in feats.iter().enumerate() {
        let m = msg(f) & bits::mask(q);
        for &t in graph.out_neighbors(n) {
            out[t] = bits::add_mod(out[t], m, q);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EngineConfig, FastBackend};

    fn engine(rows: usize) -> UpdateEngine {
        let cfg = EngineConfig::new(rows, 16);
        UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap()
    }

    #[test]
    fn csr_construction() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (3, 0)]);
        assert_eq!(g.nodes(), 4);
        assert_eq!(g.edges(), 4);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.out_neighbors(2), &[] as &[usize]);
    }

    #[test]
    fn ring_graph_shape() {
        let g = CsrGraph::ring_with_chords(8, 3);
        assert_eq!(g.nodes(), 8);
        assert_eq!(g.edges(), 16);
        assert_eq!(g.out_neighbors(7), &[0, 2]);
    }

    #[test]
    fn one_round_matches_reference() {
        let g = CsrGraph::ring_with_chords(16, 5);
        let feats: Vec<u32> = (0..16).map(|i| (i * 100 + 7) as u32).collect();
        let want = reference_round(&g, &feats, 16, |f| f >> 1);

        let mut ge = GraphEngine::new(g, engine(128)).unwrap();
        ge.set_features(&feats).unwrap();
        ge.propagate_round(|f| f >> 1).unwrap();
        assert_eq!(ge.features().unwrap(), want);
        ge.close().unwrap();
    }

    #[test]
    fn multi_round_random_graph_matches_reference() {
        let g = CsrGraph::random(100, 4, 9);
        let feats: Vec<u32> = (0..100).map(|i| (i * 13 % 997) as u32).collect();

        let mut want = feats.clone();
        for _ in 0..3 {
            want = reference_round(&g, &want, 16, |f| f >> 2);
        }

        let mut ge = GraphEngine::new(g, engine(128)).unwrap();
        ge.set_features(&feats).unwrap();
        ge.run(3, 2).unwrap();
        assert_eq!(ge.features().unwrap(), want);
        let s = ge.stats();
        // ~400 messages/round × 3 rounds collapse into few batches.
        assert!(s.batches < 60, "batches = {}", s.batches);
        ge.close().unwrap();
    }

    #[test]
    fn rejects_graph_larger_than_engine() {
        let g = CsrGraph::random(200, 2, 1);
        assert!(GraphEngine::new(g, engine(128)).is_err());
    }
}
