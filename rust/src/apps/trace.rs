//! Deterministic workload traces: record an [`UpdateRequest`] stream
//! once, replay it bit-identically onto any backend / fidelity tier /
//! shard configuration.
//!
//! Every app, test and bench that wants to diff engines needs the same
//! three things: a pinned request stream, a deterministic batching
//! structure, and a host-semantics oracle. A [`Trace`] provides all
//! three:
//!
//! - **Format** — one JSON object per line (parsed with the in-repo
//!   [`crate::util::json`] parser; serde is not in the offline vendor
//!   set). The writer is canonical — fixed key order, no floats — so
//!   `serialize → parse → serialize` is byte-identical.
//! - **Determinism** — [`BackendKind::start`] builds engines with the
//!   group-commit deadline and size seals disabled, so batches seal
//!   *only* at the trace's explicit `Flush` barriers (spelled as
//!   per-shard drains — there is no whole-engine flush) plus the
//!   forced seal a write triggers when its row is pending. The batch
//!   structure, and therefore the modeled energy/latency accounting,
//!   is a pure function of the trace — never of wall-clock timing.
//! - **Oracle** — [`Trace::reference_state`] folds the events over a
//!   plain `Vec<u32>` with `util::bits` host arithmetic.
//!
//! Invariances this substrate guarantees (and the differential tests
//! in `rust/tests/integration_trace.rs` enforce): the final state is
//! bit-identical across backends, fidelity tiers and shard counts; the
//! modeled energy report is bit-identical across fidelity tiers, and
//! across shard counts for traces whose flush groups touch every
//! shard (dense traces, e.g. the VGG-7 trainer's).
//!
//! ## Wire format (`fast-trace-v1`)
//!
//! ```text
//! {"trace":"fast-trace-v1","name":"vgg7-128x8","rows":128,"q":8,"seed":"66"}
//! {"t":"w","r":0,"v":17}            # conventional-port write
//! {"t":"u","o":"add","r":5,"v":3}   # update request (add|sub|and|or|xor)
//! {"t":"f"}                         # flush barrier (seals every shard)
//! ```
//!
//! The seed is a decimal *string* because the in-repo JSON parser
//! stores numbers as `f64`, which would silently corrupt u64 seeds
//! above 2⁵³ and break the byte-identity of the round trip.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context};

use crate::coordinator::{
    BitPlaneBackend, DigitalBackend, EngineConfig, EngineStats, FastBackend, Ticket,
    UpdateEngine, UpdateOp, UpdateRequest,
};
use crate::fastmem::Fidelity;
use crate::util::bits;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::Result;

/// Format tag on the header line; bump on breaking changes.
pub const TRACE_FORMAT: &str = "fast-trace-v1";

/// Which executor family a trace (or the trainer) runs against.
///
/// `Fast(Fidelity::BitPlane)` and `BitPlane` are the same tier spelled
/// two ways; both construct the dedicated whole-shard
/// [`BitPlaneBackend`] (never the per-bank `FastBackend` bit-plane
/// variant), so label and engine can never disagree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Behavioural FAST banks at a fidelity tier (phase or word; the
    /// bit-plane tier routes to the dedicated [`BitPlaneBackend`]).
    Fast(Fidelity),
    /// The bit-sliced tier: one plane stack per shard.
    BitPlane,
    /// The paper's memory-computing-separated digital baseline.
    Digital,
}

impl BackendKind {
    /// Resolve the CLI flag pair (`--backend`, `--fidelity`) exactly
    /// like `fast serve` does: `--fidelity` applies to the fast
    /// backend only, and the bit-plane tier selects the dedicated
    /// whole-shard plane backend.
    pub fn from_flags(backend: &str, fidelity: Fidelity) -> Result<BackendKind> {
        match backend {
            "fast" => Ok(match fidelity {
                Fidelity::BitPlane => BackendKind::BitPlane,
                f => BackendKind::Fast(f),
            }),
            "bitplane" => {
                ensure!(
                    matches!(fidelity, Fidelity::WordFast | Fidelity::BitPlane),
                    "--fidelity applies to --backend fast only"
                );
                Ok(BackendKind::BitPlane)
            }
            "digital" => {
                ensure!(
                    fidelity == Fidelity::WordFast,
                    "--fidelity applies to --backend fast only"
                );
                Ok(BackendKind::Digital)
            }
            other => bail!("unknown backend {other:?} (fast|bitplane|digital)"),
        }
    }

    /// Human label matching the backend's `Backend::name`.
    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Fast(Fidelity::PhaseAccurate) => "fast-phase-accurate",
            BackendKind::Fast(Fidelity::WordFast) => "fast-behavioural",
            BackendKind::Fast(Fidelity::BitPlane) | BackendKind::BitPlane => "fast-bitplane",
            BackendKind::Digital => "digital-baseline",
        }
    }

    /// Start an update engine for deterministic replay: group-commit
    /// deadline and size seals are disabled, so batches seal only at
    /// explicit flush barriers and the batch structure (hence the
    /// modeled cost accounting) is reproducible bit for bit.
    pub fn start(&self, rows: usize, q: usize, shards: usize) -> Result<UpdateEngine> {
        let mut cfg = EngineConfig::sharded(rows, q, shards);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600);
        match *self {
            BackendKind::Fast(f) if f != Fidelity::BitPlane => {
                UpdateEngine::start(cfg, move |plan| {
                    Ok(Box::new(FastBackend::with_rows_fidelity(plan.rows, plan.q, f)))
                })
            }
            BackendKind::Fast(_) | BackendKind::BitPlane => UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
            }),
            BackendKind::Digital => UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(DigitalBackend::new(plan.rows, plan.q)))
            }),
        }
    }
}

/// One recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceEvent {
    /// A coalescible row update.
    Update(UpdateRequest),
    /// A conventional-port absolute write (flushes the owning shard).
    Write { row: usize, value: u32 },
    /// Barrier: seal and apply every shard's open batch.
    Flush,
}

/// Typed unknown/malformed-field error for `fast-trace-v1` event
/// lines. Historically extra fields were silently ignored, which made
/// typos (and new fields like `tenant` sent to an old server) succeed
/// while doing the wrong thing; now every key outside an event's
/// grammar is rejected with this root cause, which the serve protocol
/// answers as `ERR badfield …` (the connection survives — unlike
/// terminal `ERR`s the client can correct and resend). Detect with
/// `err.root_cause().downcast_ref::<BadField>().is_some()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BadField {
    pub field: String,
}

impl std::fmt::Display for BadField {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown or malformed field {:?} in fast-trace-v1 event \
             (grammar: u={{t,o,r,v[,tenant]}}, w={{t,r,v[,tenant]}}, f={{t[,tenant]}})",
            self.field
        )
    }
}

impl std::error::Error for BadField {}

impl TraceEvent {
    /// Parse one canonical `fast-trace-v1` event line, validating the
    /// row against `rows` and the operand/value against `q` bits.
    /// Shared by [`Trace::parse_jsonl`] and the single-tenant `fast
    /// serve` protocol (`crate::serve`), which speaks exactly these
    /// lines on the wire. Unknown fields — including `tenant`, which
    /// only the multi-tenant routed parser accepts — answer a typed
    /// [`BadField`] root cause instead of being silently ignored.
    pub fn parse_line(line: &str, rows: usize, q: usize) -> Result<TraceEvent> {
        let (_, event) = Self::parse_line_routed(line, &|tenant| match tenant {
            None => Ok((rows, q)),
            Some(_) => Err(anyhow::Error::new(BadField { field: "tenant".to_string() })),
        })?;
        Ok(event)
    }

    /// Zero-allocation parse of one *canonical* `fast-trace-v1` event
    /// line — exactly the bytes [`Self::to_json_line`] emits, which is
    /// what every well-behaved client (and our own tools) sends. The
    /// scanner walks the line once, builds the event straight from the
    /// byte slice, and never allocates. Any deviation — reordered
    /// keys, whitespace, unknown fields, out-of-range row/value, a
    /// `tenant` field — falls back to [`Self::parse_line`], so the
    /// full grammar is still accepted and every error (including typed
    /// [`BadField`]) is byte-identical to the slow path's: errors are
    /// always produced by the one canonical error source.
    pub fn parse_line_fast(line: &str, rows: usize, q: usize) -> Result<TraceEvent> {
        match Self::scan_canonical(line.as_bytes(), rows, q) {
            Some(event) => Ok(event),
            None => Self::parse_line(line, rows, q),
        }
    }

    /// The canonical-form scanner behind [`Self::parse_line_fast`].
    /// `None` means "not canonical or not in range" — never an error
    /// by itself.
    fn scan_canonical(b: &[u8], rows: usize, q: usize) -> Option<TraceEvent> {
        fn digits(b: &[u8]) -> Option<(u64, &[u8])> {
            let end = b.iter().position(|c| !c.is_ascii_digit()).unwrap_or(b.len());
            // No digits, or a leading zero on a multi-digit number
            // (non-canonical spelling): defer to the slow path.
            if end == 0 || end > 19 || (end > 1 && b[0] == b'0') {
                return None;
            }
            let mut n = 0u64;
            for &c in &b[..end] {
                n = n * 10 + u64::from(c - b'0');
            }
            Some((n, &b[end..]))
        }
        let row_val = |rest: &[u8]| -> Option<(usize, u32)> {
            let rest = rest.strip_prefix(b"\"r\":")?;
            let (row, rest) = digits(rest)?;
            let rest = rest.strip_prefix(b",\"v\":")?;
            let (val, rest) = digits(rest)?;
            if rest != b"}" || row >= rows as u64 || val > u64::from(bits::mask(q)) {
                return None;
            }
            Some((row as usize, val as u32))
        };
        let rest = b.strip_prefix(b"{\"t\":\"")?;
        match rest {
            b"f\"}" => Some(TraceEvent::Flush),
            _ => {
                if let Some(rest) = rest.strip_prefix(b"u\",\"o\":\"") {
                    let quote = rest.iter().position(|&c| c == b'"')?;
                    let op = match &rest[..quote] {
                        b"add" => UpdateOp::Add,
                        b"sub" => UpdateOp::Sub,
                        b"and" => UpdateOp::And,
                        b"or" => UpdateOp::Or,
                        b"xor" => UpdateOp::Xor,
                        _ => return None,
                    };
                    let (row, operand) = row_val(rest[quote + 1..].strip_prefix(b",")?)?;
                    Some(TraceEvent::Update(UpdateRequest { row, op, operand }))
                } else if let Some(rest) = rest.strip_prefix(b"w\",") {
                    let (row, value) = row_val(rest)?;
                    Some(TraceEvent::Write { row, value })
                } else {
                    None
                }
            }
        }
    }

    /// Parse one event line in a multi-tenant context: an optional
    /// `"tenant":"<name>"` field routes the event, and the caller's
    /// `shape` lookup maps the (optional) tenant name to the `(rows,
    /// q)` the row/value validation runs against — so a 4-bit tenant's
    /// values are checked against *its* mask, not a global one. Every
    /// key outside the event grammar is a typed [`BadField`].
    pub fn parse_line_routed(
        line: &str,
        shape: &dyn Fn(Option<&str>) -> Result<(usize, usize)>,
    ) -> Result<(Option<String>, TraceEvent)> {
        let v = Json::parse(line).context("trace event")?;
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow!("trace event is not a JSON object"))?;
        let kind = v
            .get("t")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_default();
        let allowed: &[&str] = match kind.as_str() {
            "u" => &["t", "o", "r", "v", "tenant"],
            "w" => &["t", "r", "v", "tenant"],
            "f" => &["t", "tenant"],
            other => bail!("unknown event type {other:?}"),
        };
        for key in obj.keys() {
            if !allowed.contains(&key.as_str()) {
                return Err(anyhow::Error::new(BadField { field: key.clone() }));
            }
        }
        let tenant = match obj.get("tenant") {
            None => None,
            Some(Json::Str(name)) => Some(name.clone()),
            Some(_) => {
                return Err(anyhow::Error::new(BadField { field: "tenant".to_string() }))
            }
        };
        let (rows, q) = shape(tenant.as_deref())?;
        let word = |v: &Json| -> Result<u32> {
            let n = v
                .get("v")
                .ok_or_else(|| anyhow!("missing value"))?
                .as_usize()
                .ok_or_else(|| anyhow!("value is not an integer"))?;
            ensure!(
                n as u64 <= bits::mask(q) as u64,
                "value {n} exceeds q={q} bits"
            );
            Ok(n as u32)
        };
        let row_of = |v: &Json| -> Result<usize> {
            let r = v
                .get("r")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("missing row"))?;
            ensure!(r < rows, "row {r} out of range {rows}");
            Ok(r)
        };
        let event = match kind.as_str() {
            "u" => {
                let op = v
                    .get("o")
                    .and_then(Json::as_str)
                    .and_then(UpdateOp::parse)
                    .ok_or_else(|| anyhow!("bad or missing op"))?;
                TraceEvent::Update(UpdateRequest {
                    row: row_of(&v)?,
                    op,
                    operand: word(&v)?,
                })
            }
            "w" => TraceEvent::Write { row: row_of(&v)?, value: word(&v)? },
            _ => TraceEvent::Flush,
        };
        Ok((tenant, event))
    }

    /// Fold this event into a host-semantics state vector — the
    /// per-event step of [`Trace::reference_state`], shared with the
    /// streaming verify path of [`replay_file`].
    pub fn fold(&self, state: &mut [u32], q: usize) {
        let m = bits::mask(q);
        match *self {
            TraceEvent::Update(req) => {
                let cur = state[req.row];
                state[req.row] = match req.op {
                    UpdateOp::Add => bits::add_mod(cur, req.operand, q),
                    UpdateOp::Sub => bits::sub_mod(cur, req.operand, q),
                    UpdateOp::And => cur & req.operand & m,
                    UpdateOp::Or => (cur | req.operand) & m,
                    UpdateOp::Xor => (cur ^ req.operand) & m,
                };
            }
            TraceEvent::Write { row, value } => state[row] = value & m,
            TraceEvent::Flush => {}
        }
    }

    /// Canonical one-line serialization (no trailing newline) — the
    /// inverse of [`Self::parse_line`] and the per-event body of
    /// [`Trace::to_jsonl`].
    pub fn to_json_line(&self) -> String {
        match *self {
            TraceEvent::Update(req) => format!(
                "{{\"t\":\"u\",\"o\":\"{}\",\"r\":{},\"v\":{}}}",
                req.op.name(),
                req.row,
                req.operand
            ),
            TraceEvent::Write { row, value } => {
                format!("{{\"t\":\"w\",\"r\":{row},\"v\":{value}}}")
            }
            TraceEvent::Flush => "{\"t\":\"f\"}".to_string(),
        }
    }
}

/// Parsed trace-header metadata (the first JSONL line).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceHeader {
    pub name: String,
    pub rows: usize,
    pub q: usize,
    pub seed: u64,
}

impl TraceHeader {
    /// Parse and validate a header line (shared by the in-memory
    /// parser and the streaming [`TraceReader`]).
    pub fn parse(header: &str) -> Result<TraceHeader> {
        let h = Json::parse(header).context("trace header")?;
        ensure!(
            h.get("trace").and_then(Json::as_str) == Some(TRACE_FORMAT),
            "not a {TRACE_FORMAT} trace (header {header:?})"
        );
        let field = |key: &str| {
            h.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("header field {key:?} missing or not an integer"))
        };
        let name = h
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("header field \"name\" missing"))?;
        ensure!(
            !name.contains(['\n', '"', '\\']),
            "trace name {name:?} contains forbidden characters"
        );
        let (rows, q) = (field("rows")?, field("q")?);
        ensure!(rows >= 1, "header rows must be >= 1");
        ensure!((1..=32).contains(&q), "header q {q} out of range 1..=32");
        let seed: u64 = h
            .get("seed")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("header field \"seed\" missing or not a decimal string"))?
            .parse()
            .map_err(|_| anyhow!("header seed is not a u64"))?;
        Ok(TraceHeader { name: name.to_string(), rows, q, seed })
    }
}

/// Streaming trace-file reader: the header parses eagerly, events
/// parse one line at a time off a `BufReader` — a multi-million-event
/// trace never has to fit in memory (the `fast trace replay` path and
/// the buffered-I/O satellite of the durability PR ride this).
pub struct TraceReader {
    header: TraceHeader,
    lines: std::io::Lines<BufReader<std::fs::File>>,
    line_no: usize,
}

impl TraceReader {
    pub fn open(path: impl AsRef<Path>) -> Result<TraceReader> {
        let path = path.as_ref();
        let file = std::fs::File::open(path)
            .with_context(|| format!("reading trace from {}", path.display()))?;
        let mut lines = BufReader::new(file).lines();
        let header_line = lines
            .next()
            .ok_or_else(|| anyhow!("empty trace: missing header line"))?
            .with_context(|| format!("reading trace header from {}", path.display()))?;
        let header = TraceHeader::parse(&header_line)?;
        Ok(TraceReader { header, lines, line_no: 1 })
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    pub fn name(&self) -> &str {
        &self.header.name
    }

    pub fn rows(&self) -> usize {
        self.header.rows
    }

    pub fn q(&self) -> usize {
        self.header.q
    }

    pub fn seed(&self) -> u64 {
        self.header.seed
    }

    /// Next event, `None` at end of file. Blank lines are tolerated;
    /// malformed lines error with their line number.
    pub fn next_event(&mut self) -> Result<Option<TraceEvent>> {
        loop {
            let Some(line) = self.lines.next() else {
                return Ok(None);
            };
            self.line_no += 1;
            let line = line.context("reading trace line")?;
            if line.is_empty() {
                continue;
            }
            let event = TraceEvent::parse_line_fast(&line, self.header.rows, self.header.q)
                .with_context(|| format!("trace line {}", self.line_no))?;
            return Ok(Some(event));
        }
    }

    /// Iterator adapter over [`Self::next_event`].
    pub fn events(&mut self) -> impl Iterator<Item = Result<TraceEvent>> + '_ {
        std::iter::from_fn(move || self.next_event().transpose())
    }
}

/// A recorded workload: header metadata plus the event stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Workload label (free-form, no newlines).
    pub name: String,
    /// Logical row space the trace addresses.
    pub rows: usize,
    /// Word width the operands were drawn for.
    pub q: usize,
    /// Seed of the generator that produced the trace (provenance).
    pub seed: u64,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new(name: impl Into<String>, rows: usize, q: usize, seed: u64) -> Self {
        let name = name.into();
        assert!(!name.contains(['\n', '"', '\\']), "trace name must be plain");
        assert!(rows >= 1 && (1..=32).contains(&q));
        Trace { name, rows, q, seed, events: Vec::new() }
    }

    /// Append an update request (row must be in range, operand in q bits).
    pub fn push_update(&mut self, req: UpdateRequest) {
        assert!(req.row < self.rows, "row {} out of range {}", req.row, self.rows);
        assert_eq!(req.operand & !bits::mask(self.q), 0, "operand exceeds q bits");
        self.events.push(TraceEvent::Update(req));
    }

    pub fn push_write(&mut self, row: usize, value: u32) {
        assert!(row < self.rows, "row {row} out of range {}", self.rows);
        assert_eq!(value & !bits::mask(self.q), 0, "value exceeds q bits");
        self.events.push(TraceEvent::Write { row, value });
    }

    pub fn push_flush(&mut self) {
        self.events.push(TraceEvent::Flush);
    }

    /// Number of update events (the workload size).
    pub fn updates(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Update(_)))
            .count()
    }

    // -- serialization ------------------------------------------------------

    /// Canonical JSON-lines serialization (fixed key order, integers
    /// only) — the round-trip `to_jsonl ∘ parse_jsonl` is the identity
    /// on bytes.
    pub fn to_jsonl(&self) -> String {
        // ~34 bytes per event line is the dense-trace average.
        let mut out = String::with_capacity(64 + self.events.len() * 34);
        out.push_str(&self.header_line());
        for e in &self.events {
            out.push_str(&e.to_json_line());
            out.push('\n');
        }
        out
    }

    /// Parse a serialized trace, validating rows/q bounds per event.
    pub fn parse_jsonl(s: &str) -> Result<Trace> {
        let mut lines = s.lines().enumerate();
        let (_, header) = lines
            .next()
            .ok_or_else(|| anyhow!("empty trace: missing header line"))?;
        let h = TraceHeader::parse(header)?;
        let mut trace = Trace::new(h.name, h.rows, h.q, h.seed);
        for (i, line) in lines {
            if line.is_empty() {
                continue; // tolerate a trailing newline
            }
            let event = TraceEvent::parse_line(line, trace.rows, trace.q)
                .with_context(|| format!("trace line {}", i + 1))?;
            trace.events.push(event);
        }
        Ok(trace)
    }

    /// Write the trace to a file, buffered: the header and each event
    /// line stream through one `BufWriter` instead of materializing
    /// the whole serialization in memory first. Byte-identical to
    /// [`Self::to_jsonl`].
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let mut w = BufWriter::new(file);
        write!(w, "{}", self.header_line())
            .and_then(|()| {
                for e in &self.events {
                    writeln!(w, "{}", e.to_json_line())?;
                }
                w.flush()
            })
            .with_context(|| format!("writing trace to {}", path.display()))
    }

    /// The canonical header line (trailing newline included).
    fn header_line(&self) -> String {
        format!(
            "{{\"trace\":\"{}\",\"name\":\"{}\",\"rows\":{},\"q\":{},\"seed\":\"{}\"}}\n",
            TRACE_FORMAT, self.name, self.rows, self.q, self.seed
        )
    }

    /// Load a trace from a file (streamed through a `BufReader`; the
    /// events end up in memory, but the serialized text never does —
    /// use [`TraceReader`] directly to avoid holding the events too).
    pub fn load(path: impl AsRef<Path>) -> Result<Trace> {
        let mut r = TraceReader::open(path.as_ref())?;
        let mut trace = Trace::new(r.name().to_string(), r.rows(), r.q(), r.seed());
        while let Some(e) = r.next_event()? {
            trace.events.push(e);
        }
        Ok(trace)
    }

    // -- replay -------------------------------------------------------------

    /// Replay onto a running engine (must match the trace's rows/q; any
    /// shard count). Consecutive updates are bulk-submitted in order
    /// *with completion tickets*; each flush barrier drains every shard
    /// individually (per-shard drain — there is no whole-engine flush
    /// anymore) and waits for the step's tickets, so the engine's
    /// per-shard commit-latency histograms record one sample per shard
    /// per step. Writes interleave exactly as recorded, and a final
    /// barrier + snapshot closes the run. The caller keeps engine
    /// ownership (and shuts it down).
    pub fn replay(&self, engine: &UpdateEngine) -> Result<ReplayReport> {
        ensure!(
            engine.config().rows == self.rows && engine.config().q == self.q,
            "engine shape {}x{} != trace shape {}x{}",
            engine.config().rows,
            engine.config().q,
            self.rows,
            self.q
        );
        replay_stream(engine, self.events.iter().copied().map(Ok))
    }

    /// Convenience: build a deterministic engine for `kind`, replay,
    /// shut it down, return the report.
    pub fn replay_on(&self, kind: BackendKind, shards: usize) -> Result<ReplayReport> {
        let engine = kind.start(self.rows, self.q, shards)?;
        let report = self.replay(&engine)?;
        engine.shutdown()?;
        Ok(report)
    }

    /// Host-semantics oracle: fold the events over a plain vector.
    pub fn reference_state(&self) -> Vec<u32> {
        let mut state = vec![0u32; self.rows];
        for e in &self.events {
            e.fold(&mut state, self.q);
        }
        state
    }
}

/// The replay engine-driving loop over any event stream — in-memory
/// ([`Trace::replay`]) or streamed off disk ([`replay_file`]). The
/// caller guarantees the events fit the engine's shape (parse-time
/// validation does this for trace files).
pub fn replay_stream(
    engine: &UpdateEngine,
    events: impl Iterator<Item = Result<TraceEvent>>,
) -> Result<ReplayReport> {
    let t0 = std::time::Instant::now();
    let mut pending: Vec<UpdateRequest> = Vec::new();
    let mut tickets: Vec<Ticket> = Vec::new();
    let mut tickets_waited = 0u64;
    for e in events {
        match e? {
            TraceEvent::Update(req) => pending.push(req),
            TraceEvent::Write { row, value } => {
                // Per-shard FIFO orders the write after the chunk.
                if !pending.is_empty() {
                    tickets.extend(engine.submit_many_ticketed(std::mem::take(&mut pending))?);
                }
                engine.write(row, value)?;
            }
            TraceEvent::Flush => {
                if !pending.is_empty() {
                    tickets.extend(engine.submit_many_ticketed(std::mem::take(&mut pending))?);
                }
                engine.drain_all()?;
                for t in tickets.drain(..) {
                    t.wait()?;
                    tickets_waited += 1;
                }
            }
        }
    }
    if !pending.is_empty() {
        tickets.extend(engine.submit_many_ticketed(std::mem::take(&mut pending))?);
    }
    engine.drain_all()?;
    for t in tickets.drain(..) {
        t.wait()?;
        tickets_waited += 1;
    }
    let final_state = engine.snapshot()?;
    Ok(ReplayReport {
        final_state,
        stats: engine.stats(),
        wall_us: t0.elapsed().as_secs_f64() * 1e6,
        tickets_waited,
    })
}

/// Outcome of a [`replay_file`] run: the trace's header metadata plus
/// the replay report.
#[derive(Debug)]
pub struct FileReplay {
    pub name: String,
    pub rows: usize,
    pub q: usize,
    pub report: ReplayReport,
}

/// Replay a trace file without ever holding the whole file (or event
/// vector) in memory: events stream off a `BufReader` straight into
/// the engine. With `verify`, the host-semantics oracle folds
/// incrementally alongside and the final state must match it
/// bit-for-bit.
pub fn replay_file(
    path: impl AsRef<Path>,
    kind: BackendKind,
    shards: usize,
    verify: bool,
) -> Result<FileReplay> {
    let mut reader = TraceReader::open(path.as_ref())?;
    let (name, rows, q) = (reader.name().to_string(), reader.rows(), reader.q());
    let engine = kind.start(rows, q, shards)?;
    let mut reference = if verify { Some(vec![0u32; rows]) } else { None };
    let report = {
        let reference = &mut reference;
        replay_stream(
            &engine,
            reader.events().map(|e| {
                if let (Ok(ev), Some(state)) = (&e, reference.as_mut()) {
                    ev.fold(state, q);
                }
                e
            }),
        )?
    };
    engine.shutdown()?;
    if let Some(want) = reference {
        ensure!(
            report.final_state == want,
            "replay diverged from host semantics"
        );
    }
    Ok(FileReplay { name, rows, q, report })
}

/// Outcome of one replay.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub final_state: Vec<u32>,
    pub stats: EngineStats,
    pub wall_us: f64,
    /// Completion tickets the replay waited on (one per shard touched
    /// per step — every one resolved, or the replay errored).
    pub tickets_waited: u64,
}

/// FNV-1a digest of a row-state vector — a compact fingerprint for
/// replay reports and cross-run diffing.
pub fn state_digest(state: &[u32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &w in state {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// A seeded uniform-random add/sub trace with periodic flush barriers
/// — the generic smoke workload for `fast trace record --workload
/// uniform` and the round-trip tests.
pub fn uniform_trace(rows: usize, q: usize, updates: usize, seed: u64) -> Trace {
    let mut trace = Trace::new(format!("uniform-{rows}x{q}"), rows, q, seed);
    let mut rng = Rng::new(seed);
    let flush_every = rows.max(64);
    for i in 0..updates {
        let row = rng.below(rows as u64) as usize;
        let v = 1 + rng.below(bits::mask(q) as u64) as u32;
        let req = if rng.chance(0.25) {
            UpdateRequest::sub(row, v)
        } else {
            UpdateRequest::add(row, v)
        };
        trace.push_update(req);
        if (i + 1) % flush_every == 0 {
            trace.push_flush();
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_parse_agrees_with_slow_parse_on_canonical_lines() {
        use crate::util::quickprop::check;
        check("parse_line_fast == parse_line (canonical)", 400, |g| {
            let rows = 1 + g.u32_below(512) as usize;
            let q = 1 + g.u32_below(32) as usize;
            let event = match g.u32_below(3) {
                0 => TraceEvent::Update(UpdateRequest {
                    row: g.u32_below(rows as u32) as usize,
                    op: *g.choose(&[
                        UpdateOp::Add,
                        UpdateOp::Sub,
                        UpdateOp::And,
                        UpdateOp::Or,
                        UpdateOp::Xor,
                    ]),
                    operand: g.u32_any() & bits::mask(q),
                }),
                1 => TraceEvent::Write {
                    row: g.u32_below(rows as u32) as usize,
                    value: g.u32_any() & bits::mask(q),
                },
                _ => TraceEvent::Flush,
            };
            let line = event.to_json_line();
            // The fast path must take the scanner (not the fallback)
            // on canonical in-range lines, and agree with the slow
            // parser bit for bit.
            TraceEvent::scan_canonical(line.as_bytes(), rows, q) == Some(event)
                && TraceEvent::parse_line(&line, rows, q).ok() == Some(event)
        });
    }

    #[test]
    fn fast_parse_falls_back_with_identical_errors() {
        // Structurally canonical but out of range: the scanner bows
        // out and the slow path's message comes through verbatim.
        let cases = [
            ("{\"t\":\"u\",\"o\":\"add\",\"r\":99,\"v\":1}", "row 99 out of range 8"),
            ("{\"t\":\"u\",\"o\":\"add\",\"r\":1,\"v\":256}", "value 256 exceeds q=8"),
            ("{\"t\":\"w\",\"r\":1,\"v\":999}", "value 999 exceeds q=8"),
            ("{\"t\":\"u\",\"o\":\"nand\",\"r\":1,\"v\":1}", "bad or missing op"),
            ("{\"t\":\"x\"}", "unknown event type"),
        ];
        for (line, want) in cases {
            let fast = TraceEvent::parse_line_fast(line, 8, 8).unwrap_err();
            let slow = TraceEvent::parse_line(line, 8, 8).unwrap_err();
            assert_eq!(format!("{fast:#}"), format!("{slow:#}"), "line {line:?}");
            assert!(format!("{fast:#}").contains(want), "line {line:?}: {fast:#}");
        }
        // Non-canonical spellings still parse (via the fallback) to
        // the same events.
        for (loose, canon) in [
            ("{ \"t\": \"f\" }", "{\"t\":\"f\"}"),
            ("{\"r\":3,\"v\":7,\"t\":\"w\"}", "{\"t\":\"w\",\"r\":3,\"v\":7}"),
            ("{\"t\":\"u\",\"r\":2,\"o\":\"xor\",\"v\":1}", "{\"t\":\"u\",\"o\":\"xor\",\"r\":2,\"v\":1}"),
        ] {
            assert_eq!(
                TraceEvent::parse_line_fast(loose.trim(), 8, 8).unwrap(),
                TraceEvent::parse_line_fast(canon, 8, 8).unwrap(),
                "loose spelling {loose:?}"
            );
        }
        // A tenant field is still a typed BadField through the fast
        // entry point.
        let err = TraceEvent::parse_line_fast(
            "{\"t\":\"f\",\"tenant\":\"db\"}", 8, 8,
        )
        .unwrap_err();
        assert!(err.root_cause().downcast_ref::<BadField>().is_some());
    }

    fn tiny_trace() -> Trace {
        let mut t = Trace::new("tiny", 8, 8, 1);
        t.push_write(0, 0xAB);
        t.push_update(UpdateRequest::add(0, 4));
        t.push_update(UpdateRequest::sub(1, 1));
        t.push_update(UpdateRequest { row: 2, op: UpdateOp::Or, operand: 0x0F });
        t.push_flush();
        t.push_update(UpdateRequest { row: 0, op: UpdateOp::And, operand: 0xF0 });
        t.push_update(UpdateRequest { row: 3, op: UpdateOp::Xor, operand: 0x55 });
        t
    }

    #[test]
    fn serialization_round_trips_byte_identically() {
        let t = tiny_trace();
        let s1 = t.to_jsonl();
        let parsed = Trace::parse_jsonl(&s1).unwrap();
        assert_eq!(parsed, t);
        assert_eq!(parsed.to_jsonl(), s1, "canonical writer must be stable");
    }

    #[test]
    fn reference_state_applies_all_ops() {
        let t = tiny_trace();
        let s = t.reference_state();
        assert_eq!(s[0], (0xAB + 4) & 0xF0);
        assert_eq!(s[1], 0xFF); // 0 - 1 mod 256
        assert_eq!(s[2], 0x0F);
        assert_eq!(s[3], 0x55);
        assert_eq!(s[4], 0);
    }

    #[test]
    fn replay_matches_reference() {
        let t = uniform_trace(32, 8, 500, 7);
        let rep = t.replay_on(BackendKind::Fast(Fidelity::WordFast), 1).unwrap();
        assert_eq!(rep.final_state, t.reference_state());
        assert_eq!(rep.stats.completed, 500);
        assert!(rep.stats.modeled_energy_pj > 0.0);
        // The ticketed replay path resolved one ack per shard per step.
        assert!(rep.tickets_waited > 0);
        assert_eq!(rep.stats.tickets_resolved, rep.tickets_waited);
        assert!(rep.stats.shards[0].commit_wall.count > 0);
        assert!(rep.stats.shards[0].commit_modeled.count > 0);
    }

    #[test]
    fn event_lines_round_trip_through_parse_line() {
        let t = tiny_trace();
        for e in &t.events {
            let line = e.to_json_line();
            assert_eq!(TraceEvent::parse_line(&line, t.rows, t.q).unwrap(), *e, "{line}");
        }
        // Validation still applies per line.
        assert!(TraceEvent::parse_line("{\"t\":\"w\",\"r\":99,\"v\":0}", 8, 8).is_err());
        assert!(TraceEvent::parse_line("{\"t\":\"u\",\"o\":\"add\",\"r\":0,\"v\":256}", 8, 8).is_err());
        assert!(TraceEvent::parse_line("not json", 8, 8).is_err());
    }

    #[test]
    fn unknown_fields_are_typed_badfield_not_silently_ignored() {
        // Typos and future fields used to parse fine with the extra key
        // dropped; they must now carry a BadField root cause.
        for (line, field) in [
            ("{\"t\":\"u\",\"o\":\"add\",\"r\":0,\"v\":1,\"row\":3}", "row"),
            ("{\"t\":\"w\",\"r\":0,\"v\":1,\"o\":\"add\"}", "o"),
            ("{\"t\":\"f\",\"seq\":9}", "seq"),
            // The tenant field is reserved for the routed (multi-tenant)
            // parser — on the single-tenant path it is unknown.
            ("{\"t\":\"f\",\"tenant\":\"a\"}", "tenant"),
            // A non-string tenant is malformed even on the routed path.
            ("{\"t\":\"f\",\"tenant\":7}", "tenant"),
        ] {
            let e = TraceEvent::parse_line(line, 8, 8).unwrap_err();
            let bad = e.root_cause().downcast_ref::<BadField>();
            assert_eq!(bad, Some(&BadField { field: field.to_string() }), "{line}: {e:#}");
        }
        // Non-object events are errors, not panics.
        assert!(TraceEvent::parse_line("[1,2]", 8, 8).is_err());
        // parse_jsonl inherits the strictness.
        let hdr = "{\"trace\":\"fast-trace-v1\",\"name\":\"x\",\"rows\":4,\"q\":8,\"seed\":\"0\"}\n";
        assert!(Trace::parse_jsonl(&format!("{hdr}{{\"t\":\"f\",\"extra\":1}}\n")).is_err());
    }

    #[test]
    fn routed_parse_validates_against_the_tenant_shape() {
        let shape = |tenant: Option<&str>| -> crate::Result<(usize, usize)> {
            match tenant {
                None => Ok((8, 8)),
                Some("narrow") => Ok((4, 4)),
                Some(other) => anyhow::bail!("unknown tenant {other:?}"),
            }
        };
        // No tenant field → default shape, no routing.
        let (t, e) =
            TraceEvent::parse_line_routed("{\"t\":\"w\",\"r\":7,\"v\":255}", &shape).unwrap();
        assert_eq!(t, None);
        assert_eq!(e, TraceEvent::Write { row: 7, value: 255 });
        // Routed events validate row and value against *their* tenant's
        // rows and q, not the default's.
        let (t, e) = TraceEvent::parse_line_routed(
            "{\"t\":\"u\",\"o\":\"add\",\"r\":3,\"v\":15,\"tenant\":\"narrow\"}",
            &shape,
        )
        .unwrap();
        assert_eq!(t.as_deref(), Some("narrow"));
        assert_eq!(e, TraceEvent::Update(UpdateRequest::add(3, 15)));
        for bad in [
            "{\"t\":\"w\",\"r\":4,\"v\":0,\"tenant\":\"narrow\"}", // row ok globally, over for narrow
            "{\"t\":\"w\",\"r\":0,\"v\":16,\"tenant\":\"narrow\"}", // value over q=4 bits
            "{\"t\":\"f\",\"tenant\":\"ghost\"}",                   // shape lookup fails
        ] {
            assert!(TraceEvent::parse_line_routed(bad, &shape).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_rejects_malformed_traces() {
        assert!(Trace::parse_jsonl("").is_err());
        assert!(Trace::parse_jsonl("{\"trace\":\"other-v9\"}\n").is_err());
        // Malformed headers must be clean errors, never panics: numeric
        // seed (f64 would corrupt u64 seeds), out-of-range q/rows,
        // forbidden name characters.
        for bad in [
            "{\"trace\":\"fast-trace-v1\",\"name\":\"x\",\"rows\":4,\"q\":8,\"seed\":0}\n",
            "{\"trace\":\"fast-trace-v1\",\"name\":\"x\",\"rows\":4,\"q\":33,\"seed\":\"0\"}\n",
            "{\"trace\":\"fast-trace-v1\",\"name\":\"x\",\"rows\":0,\"q\":8,\"seed\":\"0\"}\n",
            "{\"trace\":\"fast-trace-v1\",\"name\":\"a\\\"b\",\"rows\":4,\"q\":8,\"seed\":\"0\"}\n",
        ] {
            assert!(Trace::parse_jsonl(bad).is_err(), "{bad:?}");
        }
        let hdr = "{\"trace\":\"fast-trace-v1\",\"name\":\"x\",\"rows\":4,\"q\":8,\"seed\":\"0\"}\n";
        // Row out of range.
        assert!(Trace::parse_jsonl(&format!("{hdr}{{\"t\":\"w\",\"r\":4,\"v\":0}}\n")).is_err());
        // Operand exceeds q bits.
        assert!(Trace::parse_jsonl(&format!(
            "{hdr}{{\"t\":\"u\",\"o\":\"add\",\"r\":0,\"v\":256}}\n"
        ))
        .is_err());
        // Unknown op / event type.
        assert!(Trace::parse_jsonl(&format!(
            "{hdr}{{\"t\":\"u\",\"o\":\"nand\",\"r\":0,\"v\":1}}\n"
        ))
        .is_err());
        assert!(Trace::parse_jsonl(&format!("{hdr}{{\"t\":\"z\"}}\n")).is_err());
        // Valid minimal trace parses.
        assert!(Trace::parse_jsonl(hdr).is_ok());
    }

    #[test]
    fn seeds_above_f64_precision_round_trip() {
        // 2^53 + 1 is not representable as f64 — the string encoding
        // must carry it exactly.
        let t = Trace::new("big-seed", 4, 8, (1u64 << 53) + 1);
        let s = t.to_jsonl();
        let parsed = Trace::parse_jsonl(&s).unwrap();
        assert_eq!(parsed.seed, (1u64 << 53) + 1);
        assert_eq!(parsed.to_jsonl(), s);
    }

    #[test]
    fn replay_rejects_shape_mismatch() {
        let t = tiny_trace();
        let engine = BackendKind::Fast(Fidelity::WordFast).start(16, 8, 1).unwrap();
        assert!(t.replay(&engine).is_err(), "rows mismatch must be rejected");
        engine.shutdown().unwrap();
    }

    #[test]
    fn backend_kind_flag_resolution() {
        assert_eq!(
            BackendKind::from_flags("fast", Fidelity::WordFast).unwrap(),
            BackendKind::Fast(Fidelity::WordFast)
        );
        assert_eq!(
            BackendKind::from_flags("fast", Fidelity::BitPlane).unwrap(),
            BackendKind::BitPlane
        );
        assert_eq!(
            BackendKind::from_flags("digital", Fidelity::WordFast).unwrap(),
            BackendKind::Digital
        );
        assert!(BackendKind::from_flags("digital", Fidelity::BitPlane).is_err());
        assert!(BackendKind::from_flags("bitplane", Fidelity::PhaseAccurate).is_err());
        assert!(BackendKind::from_flags("tpu", Fidelity::WordFast).is_err());
    }

    #[test]
    fn both_bitplane_spellings_run_the_dedicated_backend() {
        let t = uniform_trace(32, 8, 300, 3);
        let a = t.replay_on(BackendKind::Fast(Fidelity::BitPlane), 1).unwrap();
        let b = t.replay_on(BackendKind::BitPlane, 1).unwrap();
        assert_eq!(a.stats.backend, "fast-bitplane");
        assert_eq!(a.stats.backend, b.stats.backend, "label and engine must agree");
        assert_eq!(a.final_state, b.final_state);
        assert_eq!(a.stats.modeled_energy_pj, b.stats.modeled_energy_pj);
    }

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        std::env::temp_dir().join(format!(
            "fast-trace-{tag}-{}-{nanos}.jsonl",
            std::process::id()
        ))
    }

    #[test]
    fn buffered_save_and_streaming_reader_round_trip() {
        let t = tiny_trace();
        let path = tmpfile("roundtrip");
        t.save(&path).unwrap();
        // Bytes on disk are the canonical serialization.
        assert_eq!(std::fs::read_to_string(&path).unwrap(), t.to_jsonl());
        // The streaming reader yields the same header + events.
        let mut r = TraceReader::open(&path).unwrap();
        assert_eq!(
            r.header(),
            &TraceHeader { name: t.name.clone(), rows: t.rows, q: t.q, seed: t.seed }
        );
        let events: Vec<TraceEvent> = r.events().collect::<Result<_>>().unwrap();
        assert_eq!(events, t.events);
        // Trace::load goes through the same reader.
        assert_eq!(Trace::load(&path).unwrap(), t);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn streaming_reader_reports_bad_lines_with_numbers() {
        let path = tmpfile("badline");
        let hdr = "{\"trace\":\"fast-trace-v1\",\"name\":\"x\",\"rows\":4,\"q\":8,\"seed\":\"0\"}\n";
        std::fs::write(&path, format!("{hdr}{{\"t\":\"w\",\"r\":0,\"v\":1}}\nnot json\n")).unwrap();
        let mut r = TraceReader::open(&path).unwrap();
        assert!(r.next_event().unwrap().is_some());
        let err = r.next_event().unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replay_file_streams_and_verifies() {
        let t = uniform_trace(32, 8, 400, 19);
        let path = tmpfile("replayfile");
        t.save(&path).unwrap();
        let fr = replay_file(&path, BackendKind::Fast(Fidelity::WordFast), 2, true).unwrap();
        assert_eq!(fr.rows, 32);
        assert_eq!(fr.q, 8);
        assert_eq!(fr.report.final_state, t.reference_state());
        assert_eq!(fr.report.stats.completed, 400);
        // A corrupted event value must fail verification cleanly.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"t\":\"u\",\"o\":\"nand\",\"r\":0,\"v\":1}\n");
        std::fs::write(&path, text).unwrap();
        assert!(replay_file(&path, BackendKind::Fast(Fidelity::WordFast), 1, true).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn state_digest_discriminates() {
        let a = state_digest(&[1, 2, 3]);
        let b = state_digest(&[1, 2, 4]);
        let c = state_digest(&[1, 2, 3]);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }
}
