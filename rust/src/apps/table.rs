//! Delta-update table store — the paper's database motivation ("the
//! table update in a database", "delta update of a cache table").
//!
//! A fixed-capacity key→counter table: keys hash to rows of the FAST
//! array (open addressing for collisions); counter mutations become
//! row-update requests through the coordinator, so thousands of
//! concurrent deltas collapse into a handful of fully-concurrent batch
//! ops.

use std::collections::HashMap;

use anyhow::{anyhow, bail};

use crate::coordinator::{Ticket, UpdateEngine, UpdateRequest};
use crate::Result;

/// A key→counter table backed by the update engine.
pub struct DeltaTable {
    engine: UpdateEngine,
    /// key → row assignment.
    index: HashMap<u64, usize>,
    /// row occupancy (open addressing).
    occupied: Vec<bool>,
    capacity: usize,
}

impl DeltaTable {
    /// Wrap an engine; capacity = engine rows.
    pub fn new(engine: UpdateEngine) -> Self {
        let capacity = engine.config().rows;
        DeltaTable {
            engine,
            index: HashMap::with_capacity(capacity),
            occupied: vec![false; capacity],
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Row assigned to `key`, inserting if new. Errors when full.
    fn row_for(&mut self, key: u64) -> Result<usize> {
        if let Some(&row) = self.index.get(&key) {
            return Ok(row);
        }
        if self.index.len() >= self.capacity {
            bail!("table full ({} keys)", self.capacity);
        }
        // Open addressing: splitmix the key and probe linearly.
        let mut h = key;
        let hashed = crate::util::rng::splitmix64(&mut h) as usize;
        let mut row = hashed % self.capacity;
        while self.occupied[row] {
            row = (row + 1) % self.capacity;
        }
        self.occupied[row] = true;
        self.index.insert(key, row);
        Ok(row)
    }

    /// key += delta (mod 2^q). Creates the key at 0 if absent.
    pub fn increment(&mut self, key: u64, delta: u32) -> Result<()> {
        let row = self.row_for(key)?;
        self.engine.submit_blocking(UpdateRequest::add(row, delta))
    }

    /// key -= delta (mod 2^q). Creates the key at 0 if absent.
    pub fn decrement(&mut self, key: u64, delta: u32) -> Result<()> {
        let row = self.row_for(key)?;
        self.engine.submit_blocking(UpdateRequest::sub(row, delta))
    }

    /// key += delta with a completion [`Ticket`]: the ticket resolves
    /// (with the shard's commit_seq and modeled latency) once the
    /// delta's batch is applied — a durable ack without flushing
    /// anything.
    pub fn increment_acked(&mut self, key: u64, delta: u32) -> Result<Ticket> {
        let row = self.row_for(key)?;
        self.engine.submit_blocking_ticketed(UpdateRequest::add(row, delta))
    }

    /// key -= delta with a completion [`Ticket`].
    pub fn decrement_acked(&mut self, key: u64, delta: u32) -> Result<Ticket> {
        let row = self.row_for(key)?;
        self.engine.submit_blocking_ticketed(UpdateRequest::sub(row, delta))
    }

    /// Commit every pending delta for the shard owning `key`'s row
    /// (per-shard drain; other shards keep batching). Returns that
    /// shard's last commit sequence number.
    pub fn commit_key(&mut self, key: u64) -> Result<u64> {
        let row = *self
            .index
            .get(&key)
            .ok_or_else(|| anyhow!("key {key} not present"))?;
        let shard = self.engine.shard_of(row)?;
        self.engine.drain_shard(shard)
    }

    /// Current value. Read-your-writes without a global flush: only
    /// the owning shard — and only when it actually pends a delta for
    /// this key's row — seals its open batch.
    pub fn get(&mut self, key: u64) -> Result<u32> {
        let row = *self
            .index
            .get(&key)
            .ok_or_else(|| anyhow!("key {key} not present"))?;
        self.engine.read(row)
    }

    /// Set a key to an absolute value (conventional-port write).
    pub fn put(&mut self, key: u64, value: u32) -> Result<()> {
        let row = self.row_for(key)?;
        self.engine.write(row, value)
    }

    /// All (key, value) pairs, via one consistent snapshot.
    pub fn scan(&mut self) -> Result<Vec<(u64, u32)>> {
        let snap = self.engine.snapshot()?;
        let mut out: Vec<(u64, u32)> = self
            .index
            .iter()
            .map(|(&k, &row)| (k, snap[row]))
            .collect();
        out.sort_unstable();
        Ok(out)
    }

    /// Engine statistics (batching efficiency, modeled cost).
    pub fn stats(&self) -> crate::coordinator::EngineStats {
        self.engine.stats()
    }

    /// Shut the table down, flushing pending work.
    pub fn close(self) -> Result<()> {
        self.engine.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EngineConfig, FastBackend};

    fn table(rows: usize) -> DeltaTable {
        let cfg = EngineConfig::new(rows, 16);
        let e = UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        DeltaTable::new(e)
    }

    #[test]
    fn increment_get_roundtrip() {
        let mut t = table(128);
        t.increment(42, 10).unwrap();
        t.increment(42, 5).unwrap();
        t.increment(1000, 7).unwrap();
        t.decrement(42, 3).unwrap();
        assert_eq!(t.get(42).unwrap(), 12);
        assert_eq!(t.get(1000).unwrap(), 7);
        t.close().unwrap();
    }

    #[test]
    fn acked_increments_resolve_and_read_back() {
        let mut t = table(128);
        let t1 = t.increment_acked(7, 40).unwrap();
        let t2 = t.decrement_acked(7, 1).unwrap();
        let seq = t.commit_key(7).unwrap();
        let c1 = t1.wait().unwrap();
        let c2 = t2.wait().unwrap();
        assert!(c1.commit_seq <= seq && c2.commit_seq <= seq);
        assert!(c1.modeled_ns > 0.0);
        assert_eq!(t.get(7).unwrap(), 39);
        assert!(t.stats().tickets_resolved >= 2);
        t.close().unwrap();
    }

    #[test]
    fn missing_key_errors() {
        let mut t = table(128);
        assert!(t.get(99).is_err());
    }

    #[test]
    fn put_overwrites() {
        let mut t = table(128);
        t.increment(5, 3).unwrap();
        t.put(5, 1000).unwrap();
        t.increment(5, 1).unwrap();
        assert_eq!(t.get(5).unwrap(), 1001);
    }

    #[test]
    fn collision_handling_many_keys() {
        let mut t = table(128);
        for k in 0..128u64 {
            t.increment(k, (k + 1) as u32).unwrap();
        }
        for k in 0..128u64 {
            assert_eq!(t.get(k).unwrap(), (k + 1) as u32, "key {k}");
        }
        assert_eq!(t.len(), 128);
        // 129th key must fail.
        assert!(t.increment(9999, 1).is_err());
    }

    #[test]
    fn scan_returns_all_pairs() {
        let mut t = table(128);
        for k in [3u64, 1, 2] {
            t.increment(k, k as u32 * 10).unwrap();
        }
        let pairs = t.scan().unwrap();
        assert_eq!(pairs, vec![(1, 10), (2, 20), (3, 30)]);
    }

    #[test]
    fn batching_amortizes_many_updates() {
        let mut t = table(128);
        for i in 0..10_000u64 {
            t.increment(i % 64, 1).unwrap();
        }
        let _ = t.get(0).unwrap();
        let s = t.stats();
        assert!(
            s.batches < 10_000 / 8,
            "10k updates should collapse into few batches, got {}",
            s.batches
        );
    }
}
