//! Streaming histogram — the "high-concurrency access-intensive
//! general cache" scenario (Section II.A): many independent counters
//! receiving concurrent increments.

use anyhow::ensure;

use crate::coordinator::{UpdateEngine, UpdateRequest};
use crate::Result;

/// Fixed-bucket histogram over [lo, hi), counters in FAST rows.
pub struct Histogram {
    engine: UpdateEngine,
    lo: f64,
    hi: f64,
    buckets: usize,
}

impl Histogram {
    pub fn new(engine: UpdateEngine, lo: f64, hi: f64, buckets: usize) -> Result<Self> {
        ensure!(hi > lo, "empty range");
        ensure!(buckets >= 1 && buckets <= engine.config().rows,
            "bucket count {} exceeds engine rows {}", buckets, engine.config().rows);
        Ok(Histogram { engine, lo, hi, buckets })
    }

    pub fn buckets(&self) -> usize {
        self.buckets
    }

    /// Bucket index for a value (clamped to the edge buckets).
    pub fn bucket_of(&self, v: f64) -> usize {
        if v < self.lo {
            return 0;
        }
        let idx = ((v - self.lo) / (self.hi - self.lo) * self.buckets as f64) as usize;
        idx.min(self.buckets - 1)
    }

    /// Record one observation.
    pub fn record(&mut self, v: f64) -> Result<()> {
        let b = self.bucket_of(v);
        self.engine.submit_blocking(UpdateRequest::add(b, 1))
    }

    /// Record with a weight.
    pub fn record_weighted(&mut self, v: f64, weight: u32) -> Result<()> {
        let b = self.bucket_of(v);
        self.engine.submit_blocking(UpdateRequest::add(b, weight))
    }

    /// Bucket counts (consistent snapshot).
    pub fn counts(&mut self) -> Result<Vec<u32>> {
        let snap = self.engine.snapshot()?;
        Ok(snap[..self.buckets].to_vec())
    }

    pub fn total(&mut self) -> Result<u64> {
        Ok(self.counts()?.iter().map(|&c| c as u64).sum())
    }

    pub fn stats(&self) -> crate::coordinator::EngineStats {
        self.engine.stats()
    }

    pub fn close(self) -> Result<()> {
        self.engine.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EngineConfig, FastBackend};
    use crate::util::rng::Rng;

    fn engine(rows: usize) -> UpdateEngine {
        let cfg = EngineConfig::new(rows, 16);
        UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap()
    }

    #[test]
    fn bucket_mapping() {
        let h = Histogram::new(engine(128), 0.0, 10.0, 10).unwrap();
        assert_eq!(h.bucket_of(-5.0), 0);
        assert_eq!(h.bucket_of(0.0), 0);
        assert_eq!(h.bucket_of(5.0), 5);
        assert_eq!(h.bucket_of(9.999), 9);
        assert_eq!(h.bucket_of(50.0), 9);
    }

    #[test]
    fn counts_match_reference() {
        let mut h = Histogram::new(engine(128), 0.0, 1.0, 16).unwrap();
        let mut rng = Rng::new(5);
        let mut want = vec![0u32; 16];
        for _ in 0..5000 {
            let v = rng.f64();
            want[h.bucket_of(v)] += 1;
            h.record(v).unwrap();
        }
        assert_eq!(h.counts().unwrap(), want);
        assert_eq!(h.total().unwrap(), 5000);
        let s = h.stats();
        assert!(s.rows_per_batch > 1.0);
        h.close().unwrap();
    }

    #[test]
    fn weighted_records() {
        let mut h = Histogram::new(engine(128), 0.0, 4.0, 4).unwrap();
        h.record_weighted(0.5, 10).unwrap();
        h.record_weighted(3.5, 7).unwrap();
        assert_eq!(h.counts().unwrap(), vec![10, 0, 0, 7]);
    }

    #[test]
    fn rejects_too_many_buckets() {
        assert!(Histogram::new(engine(128), 0.0, 1.0, 129).is_err());
    }
}
