//! # fast-sram — FAST: Fully-Concurrent Access SRAM Topology (reproduction)
//!
//! Production-grade reproduction of *"FAST: A Fully-Concurrent Access
//! SRAM Topology for High Row-wise Parallelism Applications Based on
//! Dynamic Shift Operations"* (Chen et al., IEEE TCAS-II 2022).
//!
//! The paper proposes a 10T shiftable SRAM cell + per-row 1-bit ALU so
//! that *all rows of an array update concurrently*: a q-bit add with
//! write-back takes q shift cycles regardless of the row count. This
//! crate contains every system needed to reproduce the paper without
//! its silicon:
//!
//! - [`fastmem`] — behavioural model of the shiftable cell, row, ALU
//!   and 128-row macro (Figs. 3–6), at three differential-tested
//!   fidelity tiers: phase-accurate, word-fast, and bit-plane
//!   (bit-sliced, 64 rows per machine word — the software mirror of
//!   the hardware's row-parallelism).
//! - [`analog`] — RC transient simulator + Monte Carlo variation for the
//!   dynamic-node waveform, noise-margin and eye-pattern results
//!   (Figs. 7, 8, 12).
//! - [`timing`] — two-phase non-overlapping clock generation and the
//!   VDD-vs-frequency shmoo model (Fig. 13).
//! - [`energy`] — calibrated energy / latency / area model reproducing
//!   Table I and Figs. 10, 11, 14.
//! - [`baseline`] — the conventional 6T SRAM + near-memory digital
//!   baseline the paper compares against (Fig. 9), plus a dual-port
//!   row-by-row variant (Fig. 1a).
//! - [`coordinator`] — the Layer-3 system contribution: a *sharded*
//!   concurrent update engine (shard router, per-shard coalescing
//!   batchers with a group-commit seal policy and per-shard commit
//!   sequence numbers, completion tickets, bank manager) that turns
//!   sparse update streams into fully-concurrent FAST batch ops
//!   without serializing them behind one worker — a request/response
//!   pipeline, not fire-and-forget.
//! - [`query`] — the in-array query engine: batch reductions
//!   (`popcount`/`sum`/`min`/`max`/`range_count`/masked `dot`)
//!   executed plane-wise on the bit-plane tier and as scalar
//!   references on every other backend, with the same
//!   `cell_toggles`/`alu_evals` closed-form accounting as updates and
//!   engine-level `submit_query` sequenced against per-shard commits.
//! - [`serve`] — the `fast serve` service front-end: the std-only
//!   `fast-serve-v1` line protocol (TCP multi-client or stdio)
//!   speaking `fast-trace-v1` events on the wire, with per-connection
//!   SUB (fire-and-forget) / CMT (wait-for-ticket) modes.
//! - [`durability`] — segmented CRC32-framed write-ahead log riding
//!   the engine's group-commit seals (one coalesced fsync per seal),
//!   atomic full-state snapshots, torn-tail-repairing crash recovery,
//!   and WAL→trace interop (`fast serve --wal-dir`,
//!   `fast wal inspect|verify|compact|export`).
//! - [`replication`] — WAL shipping over `fast-repl-v1`: read-only
//!   followers tail a primary's sealed frames (`fast serve
//!   --follower`), verify them with chained FNV + CRC digests,
//!   fail-stop on divergence, and promote to a fenced-epoch primary
//!   on failover (`fast promote`); includes a deterministic
//!   fault-injection proxy for tests.
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   functional artifacts (Layer 1/2); compiles against a clean-failing
//!   stub unless built with `--features pjrt`.
//! - [`apps`] — the workloads that motivate the paper: delta-update
//!   table store (database), graph feature updates, histograms, the
//!   VGG-7-shaped 8-bit weight-update trainer (the paper's headline
//!   96.0× / 4.4× task), and the deterministic trace record/replay
//!   substrate every workload, test and bench can pin engines against.
//! - [`telemetry`] — always-on observability: seeded-deterministic
//!   sampled request-span tracing over per-shard lock-free SPSC rings
//!   (zero allocations / zero locks on the hot paths), per-stage
//!   latency histograms, a bounded rate-window time series, and the
//!   Prometheus text exposition behind `fast serve --metrics-listen`,
//!   the `METRICS` wire verb and `fast stats --connect --watch`.
//! - [`metrics`], [`util`] — supporting substrates.
//!
//! See `docs/ARCHITECTURE.md` for the module → paper-artifact map and
//! the dataflow diagram of the sharded pipeline.
//!
//! ## Quickstart: the macro itself
//!
//! ```
//! use fast_sram::fastmem::FastArray;
//!
//! // A 128-row, 16-bit FAST macro (the paper's showcase chip).
//! let mut array = FastArray::new(128, 16);
//! array.write_row(0, 41);
//! // One fully-concurrent batch op: every row adds its delta in
//! // q = 16 shift cycles, regardless of the row count.
//! let mut deltas = vec![0u32; 128];
//! deltas[0] = 1;
//! array.batch_add(&deltas);
//! assert_eq!(array.read_row(0), 42);
//! ```
//!
//! ## Quickstart: the sharded update engine
//!
//! ```
//! use fast_sram::coordinator::{EngineConfig, FastBackend, UpdateEngine, UpdateRequest};
//!
//! # fn main() -> fast_sram::Result<()> {
//! // 256 logical rows striped over 4 worker shards; each shard gets
//! // its own batcher, bounded queue and backend instance.
//! let cfg = EngineConfig::sharded(256, 16, 4);
//! let engine = UpdateEngine::start(cfg, |plan| {
//!     Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
//! })?;
//! engine.submit_blocking(UpdateRequest::add(7, 35))?;
//! // A ticketed submit is a request/response round trip: the ticket
//! // resolves with the commit (shard, commit_seq, modeled ns) once
//! // the backend applies the batch.
//! let ticket = engine.submit_blocking_ticketed(UpdateRequest::add(7, 7))?;
//! assert_eq!(engine.read(7)?, 42); // read-your-writes, per shard + row
//! let commit = ticket.wait()?;
//! assert_eq!(commit.shard, 3);
//! assert!(commit.commit_seq >= 1);
//! engine.shutdown()?;
//! # Ok(())
//! # }
//! ```

pub mod analog;
pub mod apps;
pub mod baseline;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod durability;
pub mod energy;
pub mod experiments;
pub mod fastmem;
pub mod metrics;
pub mod query;
pub mod replication;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tenant;
pub mod timing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// The paper's macro height: 128 rows per FAST subarray.
pub const MACRO_ROWS: usize = 128;

/// The paper's showcase column count / Table I operand width.
pub const MACRO_COLS: usize = 16;
