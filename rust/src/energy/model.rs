//! Cost models for the three architectures the paper compares:
//!
//! - [`FastModel`] — the FAST SRAM macro (shift-based, fully concurrent)
//! - [`DigitalModel`] — the fully-digital near-memory baseline (Fig. 9):
//!   a 6T SRAM swept row-by-row through a standard-cell ALU pipeline
//! - [`DualPortModel`] — a dual-port SRAM doing row-by-row read+write
//!   concurrently (the Fig. 1a strawman)
//!
//! Every quantity derives from [`TechParams`] primitives; Table I and
//! Figs. 10/11 are regenerated from these functions (see
//! `crate::experiments`).

use super::tech::TechParams;
use crate::fastmem::BatchReport;

/// Energy + latency of one operation or batch.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cost {
    pub energy_fj: f64,
    pub latency_ns: f64,
}

impl Cost {
    pub fn energy_pj(&self) -> f64 {
        self.energy_fj / 1000.0
    }

    /// Energy efficiency in operations per nanojoule, given ops count.
    pub fn ops_per_nj(&self, ops: u64) -> f64 {
        if self.energy_fj == 0.0 {
            return 0.0;
        }
        ops as f64 / (self.energy_fj / 1e6)
    }
}

// ---------------------------------------------------------------------------
// FAST
// ---------------------------------------------------------------------------

/// Cost model for a FAST macro of `rows` rows.
#[derive(Debug, Clone)]
pub struct FastModel {
    pub p: TechParams,
}

impl Default for FastModel {
    fn default() -> Self {
        FastModel { p: TechParams::default() }
    }
}

impl FastModel {
    pub fn new(p: TechParams) -> Self {
        FastModel { p }
    }

    /// Conventional-port write of one q-bit word (bitline access).
    pub fn write_word(&self, rows: usize, q: usize) -> Cost {
        Cost {
            energy_fj: q as f64 * self.p.e_write_fast_128 * self.p.bitline_scale(rows),
            latency_ns: self.p.t_access_128 * self.p.access_scale(rows),
        }
    }

    /// Conventional-port read of one q-bit word.
    pub fn read_word(&self, rows: usize, q: usize) -> Cost {
        Cost {
            energy_fj: q as f64 * self.p.e_read_fast_128 * self.p.bitline_scale(rows),
            latency_ns: self.p.t_access_128 * self.p.access_scale(rows),
        }
    }

    /// One fully-concurrent batch op (q-bit op + write-back in *every*
    /// row): q shift cycles, energy scales with rows × cells.
    pub fn batch_op(&self, rows: usize, q: usize) -> Cost {
        let per_word = q as f64 * (q as f64 * self.p.e_shift_cell + self.p.e_fa);
        Cost {
            energy_fj: rows as f64 * per_word,
            latency_ns: q as f64 * self.p.t_shift_at(rows),
        }
    }

    /// Per-word (per-OP) cost of a batch op — Table I's "Calc." rows.
    pub fn calc_per_op(&self, rows: usize, q: usize) -> Cost {
        let b = self.batch_op(rows, q);
        Cost {
            energy_fj: b.energy_fj / rows as f64,
            latency_ns: b.latency_ns / rows as f64,
        }
    }

    /// Activity-scaled batch energy from a behavioural [`BatchReport`]:
    /// the analytic `e_shift_cell` assumes 50% toggle probability; the
    /// report's actual toggle counts refine it.
    pub fn batch_op_measured(&self, report: &BatchReport, rows: usize, _q: usize) -> Cost {
        let toggle_energy = report.cell_toggles as f64 * 2.0 * self.p.e_shift_cell;
        let alu_energy = report.alu_evals as f64 * self.p.e_fa;
        Cost {
            energy_fj: toggle_energy + alu_energy,
            latency_ns: report.cycles as f64 * self.p.t_shift_at(rows),
        }
    }
}

// ---------------------------------------------------------------------------
// Fully-digital near-memory baseline (Fig. 9)
// ---------------------------------------------------------------------------

/// Cost model for the near-memory digital baseline: 6T SRAM + pipelined
/// standard-cell read→ALU→write engine, one row at a time.
#[derive(Debug, Clone)]
pub struct DigitalModel {
    pub p: TechParams,
}

impl Default for DigitalModel {
    fn default() -> Self {
        DigitalModel { p: TechParams::default() }
    }
}

impl DigitalModel {
    pub fn new(p: TechParams) -> Self {
        DigitalModel { p }
    }

    /// Register write in the digital engine (Table I "Write Energy").
    pub fn write_word_reg(&self, q: usize) -> Cost {
        Cost {
            energy_fj: q as f64 * self.p.e_write_dff,
            latency_ns: self.p.t_access_dff,
        }
    }

    /// 6T SRAM word write (the baseline's storage side).
    pub fn write_word_sram(&self, rows: usize, q: usize) -> Cost {
        Cost {
            energy_fj: q as f64 * self.p.e_write_6t_128 * self.p.bitline_scale(rows),
            latency_ns: self.p.t_access_128 * self.p.access_scale(rows),
        }
    }

    /// 6T SRAM word read.
    pub fn read_word_sram(&self, rows: usize, q: usize) -> Cost {
        Cost {
            energy_fj: q as f64 * self.p.e_read_6t_128 * self.p.bitline_scale(rows),
            latency_ns: self.p.t_access_128 * self.p.access_scale(rows),
        }
    }

    /// One read-modify-write op on one row, amortized inside a burst
    /// sweep (Table I "Calc." rows): bitline energy × burst amortization,
    /// pipelined throughput of `digital_pipe_frac × t_access`.
    pub fn calc_per_op(&self, rows: usize, q: usize) -> Cost {
        let e_bl = (self.p.e_read_6t_128 + self.p.e_write_6t_128) * self.p.bitline_scale(rows);
        Cost {
            energy_fj: q as f64 * e_bl * self.p.eta_digital_burst,
            latency_ns: self.p.digital_pipe_frac * self.p.t_access_128 * self.p.access_scale(rows),
        }
    }

    /// Batch update of all `rows` rows — the row-by-row sweep. Latency
    /// is throughput-bound plus a two-stage pipeline fill.
    pub fn batch_update(&self, rows: usize, q: usize) -> Cost {
        let per = self.calc_per_op(rows, q);
        let fill = 2.0 * self.p.t_access_128 * self.p.access_scale(rows);
        Cost {
            energy_fj: per.energy_fj * rows as f64,
            latency_ns: per.latency_ns * rows as f64 + fill,
        }
    }
}

// ---------------------------------------------------------------------------
// Dual-port row-by-row baseline (Fig. 1a)
// ---------------------------------------------------------------------------

/// Dual-port SRAM strawman: read port + write port operate concurrently
/// but rows are still visited one at a time and the update ALU sits in
/// the periphery.
#[derive(Debug, Clone)]
pub struct DualPortModel {
    pub p: TechParams,
}

impl Default for DualPortModel {
    fn default() -> Self {
        DualPortModel { p: TechParams::default() }
    }
}

impl DualPortModel {
    pub fn new(p: TechParams) -> Self {
        DualPortModel { p }
    }

    /// Per-row update: read and write overlap (dual ports) so latency is
    /// one access; both ports burn full bitline energy (no burst
    /// amortization — ports are independently decoded), and dual-port
    /// (8T) bitlines carry ~15% extra capacitance.
    pub fn calc_per_op(&self, rows: usize, q: usize) -> Cost {
        let dual_port_cap = 1.15;
        let e_bl =
            (self.p.e_read_6t_128 + self.p.e_write_6t_128) * self.p.bitline_scale(rows) * dual_port_cap;
        Cost {
            energy_fj: q as f64 * e_bl,
            latency_ns: self.p.t_access_128 * self.p.access_scale(rows),
        }
    }

    pub fn batch_update(&self, rows: usize, q: usize) -> Cost {
        let per = self.calc_per_op(rows, q);
        Cost {
            energy_fj: per.energy_fj * rows as f64,
            latency_ns: per.latency_ns * rows as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const R: usize = 128;
    const Q: usize = 16;

    #[test]
    fn table1_fast_calc() {
        let m = FastModel::default();
        let c = m.calc_per_op(R, Q);
        assert!((c.energy_pj() - 0.38).abs() < 0.01, "{:?}", c);
        assert!((c.latency_ns - 0.025).abs() < 0.001, "{:?}", c);
    }

    #[test]
    fn table1_digital_calc() {
        let m = DigitalModel::default();
        let c = m.calc_per_op(R, Q);
        assert!((c.energy_pj() - 2.09).abs() < 0.01, "{:?}", c);
        assert!((c.latency_ns - 0.68).abs() < 0.01, "{:?}", c);
    }

    #[test]
    fn table1_headline_ratios() {
        let f = FastModel::default().calc_per_op(R, Q);
        let d = DigitalModel::default().calc_per_op(R, Q);
        let energy_ratio = d.energy_fj / f.energy_fj;
        let speed_ratio = d.latency_ns / f.latency_ns;
        assert!((energy_ratio - 5.5).abs() < 0.2, "energy ratio {energy_ratio}");
        assert!((speed_ratio - 27.2).abs() < 0.5, "speed ratio {speed_ratio}");
    }

    #[test]
    fn table1_access_energies() {
        let p = TechParams::default();
        let f = FastModel::default();
        let w = f.write_word(R, 1);
        assert!((w.energy_fj - p.e_write_fast_128).abs() < 1e-9);
        assert!((w.latency_ns - 0.94).abs() < 1e-9);
        let r = f.read_word(R, 1);
        assert!((r.energy_fj - p.e_read_fast_128).abs() < 1e-9);
    }

    #[test]
    fn fast_batch_latency_independent_of_rows() {
        let m = FastModel::default();
        let a = m.batch_op(32, 16);
        let b = m.batch_op(128, 16);
        assert_eq!(a.latency_ns, b.latency_ns);
        // ... while digital batch latency scales ~linearly with rows.
        let d = DigitalModel::default();
        let da = d.batch_update(32, 16);
        let db = d.batch_update(128, 16);
        assert!(db.latency_ns > 2.0 * da.latency_ns);
    }

    #[test]
    fn fast_wins_more_with_more_rows() {
        let f = FastModel::default();
        let d = DigitalModel::default();
        let speedup = |rows| {
            d.batch_update(rows, 16).latency_ns / f.batch_op(rows, 16).latency_ns
        };
        assert!(speedup(512) > speedup(128));
        assert!(speedup(128) > speedup(32));
    }

    #[test]
    fn energy_crossover_is_linear_in_q() {
        // FAST loses on energy only for very short arrays; the crossover
        // row count grows with bit width (paper's Fig. 10a trend).
        let f = FastModel::default();
        let d = DigitalModel::default();
        let crossover = |q: usize| -> usize {
            (1..=4096)
                .find(|&r| d.calc_per_op(r, q).energy_fj > f.calc_per_op(r, q).energy_fj)
                .unwrap_or(4096)
        };
        let c16 = crossover(16);
        let c32 = crossover(32);
        assert!(c32 > c16, "crossover must grow with q: {c16} vs {c32}");
        // Shape check: crossover stays within a small multiple of q.
        assert!(c16 <= 2 * 16 && c32 <= 2 * 32, "c16={c16} c32={c32}");
    }

    #[test]
    fn dual_port_between_digital_and_fast_on_latency() {
        let f = FastModel::default().batch_op(R, Q);
        let dp = DualPortModel::default().batch_update(R, Q);
        let dig = DigitalModel::default().batch_update(R, Q);
        assert!(f.latency_ns < dp.latency_ns);
        // dual-port is slower per batch than the pipelined digital engine
        // (one full access per row vs 0.68 ns pipelined) but both are
        // row-serial.
        assert!(dp.latency_ns > dig.latency_ns * 0.9);
    }

    #[test]
    fn measured_cost_identical_across_fidelity_tiers() {
        // The activity-scaled energy model consumes BatchReports; the
        // bit-plane tier derives its toggle/eval counts analytically
        // from plane popcounts, so the resulting Costs must be
        // bit-identical to the word-fast tier's, not just close.
        use crate::fastmem::{FastArray, Fidelity};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(12);
        let init: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
        let deltas: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
        let m = FastModel::default();
        let mut costs = Vec::new();
        for f in [Fidelity::WordFast, Fidelity::BitPlane] {
            let mut a = FastArray::with_fidelity(128, 16, f);
            a.load(&init);
            let report = a.batch_add(&deltas);
            costs.push(m.batch_op_measured(&report, 128, 16));
        }
        assert_eq!(costs[0], costs[1], "tier change must not move energy numbers");
    }

    #[test]
    fn measured_report_close_to_analytic_at_half_activity() {
        use crate::fastmem::FastArray;
        use crate::util::rng::Rng;
        let mut a = FastArray::new(128, 16);
        let mut rng = Rng::new(5);
        let init: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
        let deltas: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
        a.load(&init);
        let report = a.batch_add(&deltas);
        let m = FastModel::default();
        let measured = m.batch_op_measured(&report, 128, 16);
        let analytic = m.batch_op(128, 16);
        let ratio = measured.energy_fj / analytic.energy_fj;
        assert!((0.5..2.0).contains(&ratio), "activity ratio {ratio}");
        assert_eq!(measured.latency_ns, analytic.latency_ns);
    }
}
