//! Calibrated energy / latency / area models (paper Section III).
//!
//! - [`tech`] — technology primitives recovered from Table I and the
//!   measured shmoo points (DESIGN.md §6 derives each constant)
//! - [`model`] — per-op and per-batch cost functions for FAST, the
//!   fully-digital near-memory baseline, and the dual-port strawman
//! - [`area`] — cell/macro area and the Fig. 14 die breakdown

pub mod area;
pub mod model;
pub mod tech;

pub use area::{AreaBreakdown, AreaModel};
pub use model::{Cost, DigitalModel, DualPortModel, FastModel};
pub use tech::TechParams;
