//! Area model: cell-level and macro-level area of FAST vs conventional
//! SRAM, and the die breakdown of Fig. 14.
//!
//! Paper anchors (Section III.E):
//!   - 10T cell ⇒ ~70% cell-level overhead over 6T
//!   - shift-control generation ≈ 10% of the cell array at 16 columns
//!   - full macro ≈ 41.7% larger than the general-purpose SRAM macro

use super::tech::TechParams;

/// Area breakdown of one macro (µm²).
#[derive(Debug, Clone, PartialEq)]
pub struct AreaBreakdown {
    pub cell_array: f64,
    pub shift_ctrl: f64,
    pub row_alus: f64,
    pub decoder_precharge_sa: f64,
    pub total: f64,
}

impl AreaBreakdown {
    /// Percentages in the order: cells, shift control, row ALUs,
    /// shared peripherals (Fig. 14 pie slices).
    pub fn percentages(&self) -> Vec<(&'static str, f64)> {
        let pct = |x: f64| 100.0 * x / self.total;
        vec![
            ("cell array", pct(self.cell_array)),
            ("shift control", pct(self.shift_ctrl)),
            ("row ALUs + route", pct(self.row_alus)),
            ("decoder/precharge/SA/ctrl", pct(self.decoder_precharge_sa)),
        ]
    }
}

/// Area model over the shared technology parameters.
#[derive(Debug, Clone)]
pub struct AreaModel {
    pub p: TechParams,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel { p: TechParams::default() }
    }
}

impl AreaModel {
    pub fn new(p: TechParams) -> Self {
        AreaModel { p }
    }

    /// FAST 10T cell area (µm²).
    pub fn fast_cell(&self) -> f64 {
        self.p.area_cell_6t * (1.0 + self.p.fast_cell_overhead)
    }

    /// Conventional SRAM macro area: 6T array + shared peripherals.
    pub fn sram_macro(&self, rows: usize, cols: usize) -> f64 {
        let cells = rows as f64 * cols as f64 * self.p.area_cell_6t;
        // Peripheral area scales with the fitted fraction of a 128×16
        // reference array, with a perimeter-ish split: decoder scales
        // with rows, column circuitry with cols.
        let ref_cells = 128.0 * 16.0 * self.p.area_cell_6t;
        let periph_ref = self.p.periph_frac_of_6t_array * ref_cells;
        let periph = periph_ref * (0.5 * rows as f64 / 128.0 + 0.5 * cols as f64 / 16.0);
        cells + periph
    }

    /// FAST macro breakdown (Fig. 14).
    pub fn fast_breakdown(&self, rows: usize, cols: usize) -> AreaBreakdown {
        let cell_array = rows as f64 * cols as f64 * self.fast_cell();
        let shift_ctrl = self.p.shift_ctrl_frac * cell_array * (16.0 / cols as f64).min(1.0)
            + self.p.shift_ctrl_frac * cell_array * (1.0 - (16.0 / cols as f64).min(1.0)) * 0.5;
        let row_alus = rows as f64 * self.p.alu_area_cells * self.p.area_cell_6t;
        // Same shared peripherals as the conventional macro.
        let ref_cells = 128.0 * 16.0 * self.p.area_cell_6t;
        let periph_ref = self.p.periph_frac_of_6t_array * ref_cells;
        let periph = periph_ref * (0.5 * rows as f64 / 128.0 + 0.5 * cols as f64 / 16.0);
        let total = cell_array + shift_ctrl + row_alus + periph;
        AreaBreakdown {
            cell_array,
            shift_ctrl,
            row_alus,
            decoder_precharge_sa: periph,
            total,
        }
    }

    /// FAST macro total area.
    pub fn fast_macro(&self, rows: usize, cols: usize) -> f64 {
        self.fast_breakdown(rows, cols).total
    }

    /// Macro-level overhead of FAST vs conventional SRAM (paper: ~41.7%
    /// for 128×16).
    pub fn macro_overhead(&self, rows: usize, cols: usize) -> f64 {
        self.fast_macro(rows, cols) / self.sram_macro(rows, cols) - 1.0
    }

    /// Area-normalization factor for efficiency comparisons (Fig. 11):
    /// ops/J/area — FAST packs fewer rows into the same silicon.
    pub fn area_norm(&self, rows: usize, cols: usize) -> f64 {
        self.sram_macro(rows, cols) / self.fast_macro(rows, cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_overhead_is_70_percent() {
        let m = AreaModel::default();
        let ratio = m.fast_cell() / m.p.area_cell_6t;
        assert!((ratio - 1.70).abs() < 1e-9);
    }

    #[test]
    fn macro_overhead_near_41_7_percent() {
        let m = AreaModel::default();
        let ovh = m.macro_overhead(128, 16);
        assert!(
            (ovh - 0.417).abs() < 0.02,
            "macro overhead {:.1}% vs paper 41.7%",
            100.0 * ovh
        );
    }

    #[test]
    fn shift_ctrl_near_10_percent_of_cells_at_16_cols() {
        let m = AreaModel::default();
        let b = m.fast_breakdown(128, 16);
        let frac = b.shift_ctrl / b.cell_array;
        assert!((frac - 0.10).abs() < 0.01, "shift ctrl frac {frac}");
    }

    #[test]
    fn breakdown_sums_to_total_and_percentages_to_100() {
        let m = AreaModel::default();
        let b = m.fast_breakdown(128, 16);
        let sum = b.cell_array + b.shift_ctrl + b.row_alus + b.decoder_precharge_sa;
        assert!((sum - b.total).abs() < 1e-9);
        let pct_sum: f64 = b.percentages().iter().map(|(_, p)| p).sum();
        assert!((pct_sum - 100.0).abs() < 1e-9);
    }

    #[test]
    fn area_grows_with_rows() {
        let m = AreaModel::default();
        assert!(m.fast_macro(256, 16) > m.fast_macro(128, 16));
        assert!(m.sram_macro(256, 16) > m.sram_macro(128, 16));
    }

    #[test]
    fn area_norm_below_one() {
        let m = AreaModel::default();
        let n = m.area_norm(128, 16);
        assert!(n < 1.0 && n > 0.5, "norm {n}");
    }
}
