//! Technology calibration constants (65 nm CMOS, 1.0 V nominal).
//!
//! Every constant is recovered from the paper's published aggregates
//! (Table I, Section III.E, and the measured shmoo points) — see
//! DESIGN.md §6 for the derivations. All other datapoints in the
//! reproduction (Figs. 10, 11, 13, 14) are *derived* from these
//! primitives; there is no per-figure tuning.
//!
//! Units: energies in fJ, times in ns, areas in µm², voltages in V.

/// Technology + calibration parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct TechParams {
    // --- SRAM access energies at the reference 128-row bitline ---
    /// 6T SRAM read energy per bit at R = 128 (Table I: 68.4 fJ/bit).
    pub e_read_6t_128: f64,
    /// 6T SRAM write energy per bit at R = 128 (Table I: 72.4 fJ/bit).
    pub e_write_6t_128: f64,
    /// FAST cell read energy per bit at R = 128 (Table I: 74.8 fJ/bit —
    /// 6T cost + ~9% switch-transistor parasitics on the bitline).
    pub e_read_fast_128: f64,
    /// FAST cell write energy per bit at R = 128 (Table I: 76.2 fJ/bit).
    pub e_write_fast_128: f64,
    /// Fraction of bitline energy that is row-independent (sense amp,
    /// drivers, decoder). The rest scales linearly with rows-on-bitline.
    pub bitline_fixed_frac: f64,

    // --- FAST shift datapath ---
    /// Energy per shiftable cell per shift cycle (local TG + inverter
    /// toggle at 50% activity). Calibrated so a 16-bit add with
    /// write-back costs 0.38 pJ/OP (Table I): 16·(16·e + e_fa) = 380 fJ.
    pub e_shift_cell: f64,
    /// Energy per 1-bit FA evaluation (the row ALU).
    pub e_fa: f64,
    /// Shift cycle period at 1.0 V. Table I's 0.025 ns/OP at 128-row
    /// parallelism ⇒ 16 · t_shift / 128 = 0.025 ⇒ t_shift = 0.2 ns
    /// (the post-layout critical path of the inverter→TG→inverter hop).
    pub t_shift: f64,
    /// Shift-control skew penalty per doubling of rows beyond 128
    /// (clock-tree depth growth for taller macros).
    pub shift_skew_per_doubling: f64,

    // --- conventional SRAM timing ---
    /// Row access (read or write) time at R = 128 (Table I: 0.94 ns).
    pub t_access_128: f64,
    /// Fraction of access time that is row-independent.
    pub access_fixed_frac: f64,

    // --- fully-digital near-memory baseline (Fig. 9) ---
    /// Register (DFF) write energy per bit in the digital engine
    /// (Table I "Digital" column: 219.7 fJ/bit).
    pub e_write_dff: f64,
    /// Register access time (Table I: 0.09 ns).
    pub t_access_dff: f64,
    /// Burst-pipelining amortization of bitline energy when the digital
    /// engine sweeps rows sequentially (shared precharge, open-page
    /// bursts). Fitted so the 16-bit/128-row op costs 2.09 pJ (Table I):
    /// 16 · (68.4 + 72.4) · η = 2090 ⇒ η ≈ 0.928.
    pub eta_digital_burst: f64,
    /// Digital per-row pipeline throughput as a fraction of the access
    /// time (read/add/write stages overlapped). Fitted to Table I's
    /// 0.68 ns/OP at R = 128: 0.68 / 0.94 ≈ 0.723.
    pub digital_pipe_frac: f64,

    // --- transistor counts (Table I "Cell Structure") ---
    pub transistors_6t: u32,
    pub transistors_fast: u32,
    pub transistors_digital: u32,

    // --- area (65 nm) ---
    /// 6T SRAM cell area (µm²), typical published 65 nm value.
    pub area_cell_6t: f64,
    /// FAST 10T cell area overhead vs 6T (paper: "about 70%").
    pub fast_cell_overhead: f64,
    /// Shift-control generation area as a fraction of the FAST cell
    /// array at 16 columns (paper: "about 10%").
    pub shift_ctrl_frac: f64,
    /// Row-ALU + carry latch + route unit area per row, in units of 6T
    /// cell areas (a ~20T datapath per row).
    pub alu_area_cells: f64,
    /// Shared peripherals (decoders, precharge, sense amps, control
    /// decoder) as a multiple of the 6T cell-array area for a 128×16
    /// macro. Fitted so the full FAST macro is ~41.7% larger than the
    /// general-purpose SRAM macro (Section III.E).
    pub periph_frac_of_6t_array: f64,

    // --- supply / shmoo calibration (Fig. 13, Section abstract) ---
    /// Nominal supply.
    pub vdd_nominal: f64,
    /// NMOS/PMOS threshold magnitude used by the alpha-power model.
    pub v_th: f64,
    /// Alpha-power-law velocity-saturation exponent. Fitted to the two
    /// measured shmoo points (800 MHz @ 1.0 V, 1.2 GHz @ 1.2 V).
    pub alpha_power: f64,
    /// f_max(vdd_nominal) of the fabricated macro: 0.8 GHz.
    pub f_max_nominal_ghz: f64,
}

impl Default for TechParams {
    fn default() -> Self {
        TechParams {
            e_read_6t_128: 68.4,
            e_write_6t_128: 72.4,
            e_read_fast_128: 74.8,
            e_write_fast_128: 76.2,
            bitline_fixed_frac: 0.10,

            // 16·(16·1.17 + 4.88) = 377.6 fJ ≈ 0.38 pJ (Table I)
            e_shift_cell: 1.17,
            e_fa: 4.88,
            t_shift: 0.2,
            shift_skew_per_doubling: 0.02,

            t_access_128: 0.94,
            access_fixed_frac: 0.30,

            e_write_dff: 219.7,
            t_access_dff: 0.09,
            eta_digital_burst: 0.9278,
            digital_pipe_frac: 0.7234,

            transistors_6t: 6,
            transistors_fast: 10,
            transistors_digital: 20,

            area_cell_6t: 0.525,
            fast_cell_overhead: 0.70,
            shift_ctrl_frac: 0.10,
            alu_area_cells: 2.0,
            periph_frac_of_6t_array: 1.386,

            vdd_nominal: 1.0,
            v_th: 0.45,
            alpha_power: 1.8952,
            f_max_nominal_ghz: 0.8,
        }
    }
}

impl TechParams {
    /// Bitline energy scale factor for an R-row column relative to the
    /// 128-row reference: fixed fraction + linear-in-R wire/cell load.
    pub fn bitline_scale(&self, rows: usize) -> f64 {
        assert!(rows >= 1);
        self.bitline_fixed_frac + (1.0 - self.bitline_fixed_frac) * rows as f64 / 128.0
    }

    /// Access-time scale factor for an R-row array relative to 128 rows.
    pub fn access_scale(&self, rows: usize) -> f64 {
        assert!(rows >= 1);
        self.access_fixed_frac + (1.0 - self.access_fixed_frac) * rows as f64 / 128.0
    }

    /// Shift-cycle period for an R-row macro (control skew grows with
    /// the log of the row count beyond the reference height).
    pub fn t_shift_at(&self, rows: usize) -> f64 {
        let doublings = if rows > 128 {
            (rows as f64 / 128.0).log2()
        } else {
            0.0
        };
        self.t_shift * (1.0 + self.shift_skew_per_doubling * doublings)
    }

    /// Max shift-clock frequency at a given supply (alpha-power law):
    /// f ∝ (V − Vth)^α / V, normalized to the measured nominal point.
    pub fn f_max_ghz(&self, vdd: f64) -> f64 {
        if vdd <= self.v_th {
            return 0.0;
        }
        let drive = |v: f64| (v - self.v_th).powf(self.alpha_power) / v;
        self.f_max_nominal_ghz * drive(vdd) / drive(self.vdd_nominal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_recover_table1_fast_calc_energy() {
        let p = TechParams::default();
        // 16-bit add + write-back, per word: q·(q·e_shift + e_fa)
        let e = 16.0 * (16.0 * p.e_shift_cell + p.e_fa);
        assert!((e - 380.0).abs() < 5.0, "calc energy {e} fJ vs 0.38 pJ");
    }

    #[test]
    fn defaults_recover_table1_digital_calc_energy() {
        let p = TechParams::default();
        let e = 16.0 * (p.e_read_6t_128 + p.e_write_6t_128) * p.eta_digital_burst;
        assert!((e - 2090.0).abs() < 5.0, "digital calc energy {e} fJ vs 2.09 pJ");
    }

    #[test]
    fn defaults_recover_table1_latencies() {
        let p = TechParams::default();
        // FAST: 16 cycles / 128 rows = 0.025 ns/OP
        assert!((16.0 * p.t_shift / 128.0 - 0.025).abs() < 1e-9);
        // Digital: 0.68 ns/OP pipelined
        let t = p.digital_pipe_frac * p.t_access_128;
        assert!((t - 0.68).abs() < 0.001, "digital op time {t}");
    }

    #[test]
    fn bitline_scale_monotonic() {
        let p = TechParams::default();
        assert!((p.bitline_scale(128) - 1.0).abs() < 1e-12);
        assert!(p.bitline_scale(32) < 1.0);
        assert!(p.bitline_scale(512) > 2.0);
    }

    #[test]
    fn shift_period_grows_slowly_with_rows() {
        let p = TechParams::default();
        assert_eq!(p.t_shift_at(128), p.t_shift);
        assert_eq!(p.t_shift_at(64), p.t_shift);
        let t1024 = p.t_shift_at(1024);
        assert!(t1024 > p.t_shift && t1024 < 1.2 * p.t_shift);
    }

    #[test]
    fn fmax_matches_measured_shmoo_points() {
        let p = TechParams::default();
        assert!((p.f_max_ghz(1.0) - 0.8).abs() < 1e-9);
        let f12 = p.f_max_ghz(1.2);
        assert!((f12 - 1.2).abs() < 0.01, "f_max(1.2V) = {f12} GHz vs 1.2");
        assert_eq!(p.f_max_ghz(0.4), 0.0); // below threshold
    }
}
