//! Experiment E-F13: **Fig. 13** — shmoo plot of the FAST macro
//! (supply voltage × clock frequency pass/fail region).
//!
//! Anchors from the abstract/measurement: 800 MHz @ 1.0 V and
//! 1.2 GHz @ 1.2 V must pass; the boundary follows the alpha-power
//! critical-path model calibrated to those two silicon points.

use crate::timing::{ShmooConfig, ShmooGrid, ShmooModel};

pub fn run() -> ShmooGrid {
    ShmooModel::default().sweep(&ShmooConfig::default())
}

pub fn run_with(cfg: &ShmooConfig) -> ShmooGrid {
    ShmooModel::default().sweep(cfg)
}

pub fn render(grid: &ShmooGrid) -> String {
    let mut s = String::new();
    s.push_str("Fig. 13 — shmoo plot (supply × frequency)\n");
    s.push_str(&grid.render());
    if let Some(f) = grid.max_pass_freq(1.0) {
        s.push_str(&format!("max pass @1.0V: {f:.2} GHz (silicon: 0.80 GHz)\n"));
    }
    if let Some(f) = grid.max_pass_freq(1.2) {
        s.push_str(&format!("max pass @1.2V: {f:.2} GHz (silicon: 1.20 GHz)\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silicon_anchor_points_in_pass_region() {
        let grid = run();
        let f10 = grid.max_pass_freq(1.0).unwrap();
        let f12 = grid.max_pass_freq(1.2).unwrap();
        assert!((f10 - 0.8).abs() < 0.11, "f_max@1.0V {f10}");
        assert!((f12 - 1.2).abs() < 0.11, "f_max@1.2V {f12}");
    }

    #[test]
    fn pass_region_monotone_in_vdd() {
        let grid = run();
        let mut last = 0.0;
        for &v in &grid.vdds {
            let f = grid.max_pass_freq(v).unwrap_or(0.0);
            assert!(f + 1e-9 >= last, "pass region shrank at {v} V");
            last = f;
        }
    }
}
