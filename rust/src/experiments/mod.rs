//! Experiment drivers — one module per table/figure in the paper's
//! evaluation (Section III). Each exposes `run(...)` returning plain
//! data plus `render(...)` producing the text artifact; the CLI
//! (`fast <experiment>`) and the benches (`cargo bench`) share these.
//!
//! | module        | paper artifact | claim it reproduces                    |
//! |---------------|----------------|----------------------------------------|
//! | [`table1`]    | Table I        | energies/latencies; 5.5× / 27.2×       |
//! | [`fig10`]     | Fig. 10        | energy & latency vs bit width          |
//! | [`fig11`]     | Fig. 11        | latency + area-norm efficiency vs rows |
//! | [`fig12`]     | Fig. 12        | leakage, eye pattern, 300 mV margin    |
//! | [`fig13`]     | Fig. 13        | shmoo: 800 MHz @1.0 V, 1.2 GHz @1.2 V  |
//! | [`fig14`]     | Fig. 14        | area breakdown; 70% / 10% / 41.7%      |
//! | [`waveforms`] | Figs. 7–8      | shift / add transients                 |
//! | [`apps_bench`]| §III.C         | workload-level FAST vs digital         |
//! | [`weight_update`] | §III headline | VGG-7 8-bit weight update; 96.0× / 4.4× |

pub mod apps_bench;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod table1;
pub mod waveforms;
pub mod weight_update;
