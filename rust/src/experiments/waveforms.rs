//! Experiments E-F7 / E-F8: **Fig. 7** (transient waveforms of the
//! shift operation) and **Fig. 8** (transient waveforms of a 4-bit add
//! with the 1-bit full adder), regenerated from the RC transient
//! simulator at the 800 MHz operating point (1.25 ns cycle).

use crate::analog::cellchain::{fig7_shift_waveforms, fig8_add_waveforms};
use crate::analog::waveform::WaveformSet;

#[derive(Debug, Clone)]
pub struct Fig7 {
    pub set: WaveformSet,
    pub initial: u32,
    pub after_full_rotation: u32,
}

#[derive(Debug, Clone)]
pub struct Fig8 {
    pub set: WaveformSet,
    pub a: u32,
    pub b: u32,
    pub result: u32,
}

pub fn run_fig7(period_ns: f64) -> Fig7 {
    let (set, initial, after) = fig7_shift_waveforms(period_ns);
    Fig7 { set, initial, after_full_rotation: after }
}

pub fn run_fig8(period_ns: f64, a: u32, b: u32) -> Fig8 {
    let (set, result) = fig8_add_waveforms(period_ns, a, b);
    Fig8 { set, a: a & 0xF, b: b & 0xF, result }
}

pub fn render_fig7(f: &Fig7, width: usize) -> String {
    let mut s = String::new();
    s.push_str("Fig. 7 — transient waveforms of the shift operation (4 cells, 4 cycles)\n");
    s.push_str(&f.set.render_ascii(width));
    s.push_str(&format!(
        "word {:#06b} -> 4 cyclic shifts -> {:#06b} (identity: {})\n",
        f.initial,
        f.after_full_rotation,
        f.initial == f.after_full_rotation
    ));
    s
}

pub fn render_fig8(f: &Fig8, width: usize) -> String {
    let mut s = String::new();
    s.push_str("Fig. 8 — transient waveforms of 4-bit add with a 1-bit full adder\n");
    s.push_str(&f.set.render_ascii(width));
    s.push_str(&format!(
        "{} + {} = {} (mod 16)  [{}]\n",
        f.a,
        f.b,
        f.result,
        if f.result == (f.a + f.b) & 0xF { "correct" } else { "WRONG" }
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_rotation_identity() {
        let f = run_fig7(1.25);
        assert_eq!(f.initial, f.after_full_rotation);
        assert!(f.set.get("phi1").is_some());
        assert!(f.set.get("Z0").is_some());
    }

    #[test]
    fn fig8_add_correct() {
        let f = run_fig8(1.25, 0b0101, 0b0110);
        assert_eq!(f.result, 0b1011);
    }

    #[test]
    fn renders_are_nonempty() {
        let s7 = render_fig7(&run_fig7(1.25), 60);
        assert!(s7.contains("Fig. 7") && s7.contains("identity: true"));
        let s8 = render_fig8(&run_fig8(1.25, 3, 4), 60);
        assert!(s8.contains("correct"));
    }
}
