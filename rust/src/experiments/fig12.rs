//! Experiment E-F12: **Fig. 12** — noise tolerance and stability:
//! dynamic-node leakage decay plus the Monte Carlo eye pattern with
//! worst-case noise margin (paper: "still a 300 mV noise margin in the
//! worst case").

use crate::analog::leak::RetentionModel;
use crate::analog::montecarlo::{McResult, MonteCarlo};
use crate::analog::waveform::Waveform;

#[derive(Debug, Clone)]
pub struct Fig12 {
    /// Leakage decay trace of the dynamic node at 1.0 V.
    pub decay: Waveform,
    /// Retention time at nominal supply (ns).
    pub retention_ns: f64,
    /// Monte Carlo eye/margin result.
    pub mc: McResult,
}

pub fn run(samples: usize, seed: u64) -> Fig12 {
    let ret = RetentionModel::default();
    let decay = ret.decay_waveform(1.0, ret.retention_ns(1.0), 100);
    let mc = MonteCarlo::default().run(samples, seed);
    Fig12 {
        decay,
        retention_ns: ret.retention_ns(1.0),
        mc,
    }
}

pub fn render(f: &Fig12) -> String {
    let mut s = String::new();
    s.push_str("Fig. 12 — noise tolerance and stability\n");
    s.push_str(&format!(
        "dynamic node retention @1.0V : {:>8.0} ns (shift open-loop window: 0.6 ns @ 800 MHz)\n",
        f.retention_ns
    ));
    s.push_str(&format!(
        "MC samples                   : {:>8}\n",
        f.mc.samples.len()
    ));
    s.push_str(&format!(
        "eye opening                  : {:>8.3} V\n",
        f.mc.eye_opening()
    ));
    s.push_str(&format!(
        "mean noise margin            : {:>8.3} V\n",
        f.mc.mean_margin()
    ));
    s.push_str(&format!(
        "worst-case noise margin      : {:>8.3} V   (paper: ~0.300 V)\n",
        f.mc.worst_margin()
    ));
    s.push_str(&format!(
        "functional yield             : {:>7.1} %\n",
        100.0 * f.mc.yield_frac()
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_reproduces_paper_claims() {
        let f = run(100, 42);
        // Retention orders of magnitude above the shift window.
        assert!(f.retention_ns > 600.0);
        // Worst-case margin in the paper's neighbourhood.
        let worst = f.mc.worst_margin();
        assert!((0.25..0.45).contains(&worst), "worst margin {worst}");
        assert_eq!(f.mc.yield_frac(), 1.0);
    }

    #[test]
    fn render_contains_key_lines() {
        let f = run(20, 1);
        let s = render(&f);
        assert!(s.contains("worst-case noise margin"));
        assert!(s.contains("functional yield"));
    }
}
