//! Experiment E-T1: regenerate **Table I** — "Comparison between SRAM
//! cache and processing in memory".
//!
//! Paper values (65 nm, 1.0 V, 128 rows, 16-bit OP):
//!
//! |                | FAST SRAM   | SRAM        | Digital      |
//! | Cell Structure | 10T         | 6T          | 20T          |
//! | Write Energy   | 76.2 fJ/bit | 72.4 fJ/bit | 219.7 fJ/bit |
//! | Read Energy    | 74.8 fJ/bit | 68.4 fJ/bit | /            |
//! | Access Time    | 0.94 ns     | 0.94 ns     | 0.09 ns      |
//! | Calc. Energy   | 0.38 pJ/OP  | /           | 2.09 pJ/OP   |
//! | Calc. Time     | 0.025 ns/OP | /           | 0.68 ns/OP   |
//!
//! Headline: 5.5× energy saving, 27.2× speedup.

use crate::energy::{DigitalModel, FastModel, TechParams};

/// One regenerated Table I with paper-vs-model columns.
#[derive(Debug, Clone)]
pub struct Table1 {
    pub rows: usize,
    pub q: usize,
    // (metric, fast, sram, digital) — NaN for "/" entries.
    pub fast_write_fj_bit: f64,
    pub sram_write_fj_bit: f64,
    pub digital_write_fj_bit: f64,
    pub fast_read_fj_bit: f64,
    pub sram_read_fj_bit: f64,
    pub fast_access_ns: f64,
    pub sram_access_ns: f64,
    pub digital_access_ns: f64,
    pub fast_calc_pj_op: f64,
    pub digital_calc_pj_op: f64,
    pub fast_calc_ns_op: f64,
    pub digital_calc_ns_op: f64,
    pub energy_ratio: f64,
    pub speed_ratio: f64,
}

/// Paper reference values for the same cells.
pub struct Table1Paper;

impl Table1Paper {
    pub const FAST_WRITE: f64 = 76.2;
    pub const SRAM_WRITE: f64 = 72.4;
    pub const DIGITAL_WRITE: f64 = 219.7;
    pub const FAST_READ: f64 = 74.8;
    pub const SRAM_READ: f64 = 68.4;
    pub const ACCESS_NS: f64 = 0.94;
    pub const DIGITAL_ACCESS_NS: f64 = 0.09;
    pub const FAST_CALC_PJ: f64 = 0.38;
    pub const DIGITAL_CALC_PJ: f64 = 2.09;
    pub const FAST_CALC_NS: f64 = 0.025;
    pub const DIGITAL_CALC_NS: f64 = 0.68;
    pub const ENERGY_RATIO: f64 = 5.5;
    pub const SPEED_RATIO: f64 = 27.2;
}

/// Regenerate Table I from the calibrated models.
pub fn run(rows: usize, q: usize) -> Table1 {
    let p = TechParams::default();
    let fast = FastModel::new(p.clone());
    let dig = DigitalModel::new(p.clone());

    let fast_calc = fast.calc_per_op(rows, q);
    let dig_calc = dig.calc_per_op(rows, q);
    Table1 {
        rows,
        q,
        fast_write_fj_bit: fast.write_word(rows, 1).energy_fj,
        sram_write_fj_bit: dig.write_word_sram(rows, 1).energy_fj,
        digital_write_fj_bit: dig.write_word_reg(1).energy_fj,
        fast_read_fj_bit: fast.read_word(rows, 1).energy_fj,
        sram_read_fj_bit: dig.read_word_sram(rows, 1).energy_fj,
        fast_access_ns: fast.read_word(rows, 1).latency_ns,
        sram_access_ns: dig.read_word_sram(rows, 1).latency_ns,
        digital_access_ns: dig.write_word_reg(1).latency_ns,
        fast_calc_pj_op: fast_calc.energy_pj(),
        digital_calc_pj_op: dig_calc.energy_pj(),
        fast_calc_ns_op: fast_calc.latency_ns,
        digital_calc_ns_op: dig_calc.latency_ns,
        energy_ratio: dig_calc.energy_fj / fast_calc.energy_fj,
        speed_ratio: dig_calc.latency_ns / fast_calc.latency_ns,
    }
}

/// Render the regenerated table with paper deltas.
pub fn render(t: &Table1) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Table I — {} rows, {}-bit OP (model vs paper)\n",
        t.rows, t.q
    ));
    s.push_str(
        "metric                 |      FAST |      SRAM |   Digital |  paper(FAST/SRAM/Dig)\n",
    );
    s.push_str(
        "-----------------------+-----------+-----------+-----------+----------------------\n",
    );
    s.push_str(&format!(
        "cell structure         |       10T |        6T |       20T |  10T / 6T / 20T\n"
    ));
    s.push_str(&format!(
        "write energy (fJ/bit)  | {:>9.1} | {:>9.1} | {:>9.1} |  {} / {} / {}\n",
        t.fast_write_fj_bit,
        t.sram_write_fj_bit,
        t.digital_write_fj_bit,
        Table1Paper::FAST_WRITE,
        Table1Paper::SRAM_WRITE,
        Table1Paper::DIGITAL_WRITE
    ));
    s.push_str(&format!(
        "read energy (fJ/bit)   | {:>9.1} | {:>9.1} |         / |  {} / {} / -\n",
        t.fast_read_fj_bit,
        t.sram_read_fj_bit,
        Table1Paper::FAST_READ,
        Table1Paper::SRAM_READ
    ));
    s.push_str(&format!(
        "access time (ns)       | {:>9.2} | {:>9.2} | {:>9.2} |  {} / {} / {}\n",
        t.fast_access_ns,
        t.sram_access_ns,
        t.digital_access_ns,
        Table1Paper::ACCESS_NS,
        Table1Paper::ACCESS_NS,
        Table1Paper::DIGITAL_ACCESS_NS
    ));
    s.push_str(&format!(
        "calc energy (pJ/OP)    | {:>9.2} |         / | {:>9.2} |  {} / - / {}\n",
        t.fast_calc_pj_op,
        t.digital_calc_pj_op,
        Table1Paper::FAST_CALC_PJ,
        Table1Paper::DIGITAL_CALC_PJ
    ));
    s.push_str(&format!(
        "calc time (ns/OP)      | {:>9.3} |         / | {:>9.2} |  {} / - / {}\n",
        t.fast_calc_ns_op,
        t.digital_calc_ns_op,
        Table1Paper::FAST_CALC_NS,
        Table1Paper::DIGITAL_CALC_NS
    ));
    s.push_str(&format!(
        "headline: energy {:.1}x (paper {:.1}x), speed {:.1}x (paper {:.1}x)\n",
        t.energy_ratio,
        Table1Paper::ENERGY_RATIO,
        t.speed_ratio,
        Table1Paper::SPEED_RATIO
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regenerated_values_match_paper_within_tolerance() {
        let t = run(128, 16);
        let close = |a: f64, b: f64, tol: f64| (a - b).abs() / b < tol;
        assert!(close(t.fast_write_fj_bit, Table1Paper::FAST_WRITE, 0.01));
        assert!(close(t.sram_write_fj_bit, Table1Paper::SRAM_WRITE, 0.01));
        assert!(close(t.digital_write_fj_bit, Table1Paper::DIGITAL_WRITE, 0.01));
        assert!(close(t.fast_read_fj_bit, Table1Paper::FAST_READ, 0.01));
        assert!(close(t.sram_read_fj_bit, Table1Paper::SRAM_READ, 0.01));
        assert!(close(t.fast_access_ns, Table1Paper::ACCESS_NS, 0.01));
        assert!(close(t.digital_access_ns, Table1Paper::DIGITAL_ACCESS_NS, 0.01));
        assert!(close(t.fast_calc_pj_op, Table1Paper::FAST_CALC_PJ, 0.02));
        assert!(close(t.digital_calc_pj_op, Table1Paper::DIGITAL_CALC_PJ, 0.02));
        assert!(close(t.fast_calc_ns_op, Table1Paper::FAST_CALC_NS, 0.02));
        assert!(close(t.digital_calc_ns_op, Table1Paper::DIGITAL_CALC_NS, 0.02));
        assert!(close(t.energy_ratio, Table1Paper::ENERGY_RATIO, 0.05));
        assert!(close(t.speed_ratio, Table1Paper::SPEED_RATIO, 0.05));
    }

    #[test]
    fn render_mentions_headline() {
        let t = run(128, 16);
        let s = render(&t);
        assert!(s.contains("Table I"));
        assert!(s.contains("headline"));
    }
}
