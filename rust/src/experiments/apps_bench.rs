//! Experiment E-APP: application-level comparison through the full
//! coordinator — the paper's Section III.C claim that FAST accelerates
//! high-concurrency update workloads (database delta updates, graph
//! feature updates) relative to the near-memory digital baseline.
//!
//! Both sides run the *same* coordinator, batcher and workload; only
//! the backend differs, so the comparison isolates the memory
//! architecture exactly like the paper's testbench does.

use std::time::Duration;

use crate::coordinator::{
    DigitalBackend, EngineConfig, FastBackend, UpdateEngine, UpdateRequest,
};
use crate::util::rng::Rng;
use crate::Result;

/// Workload shape.
#[derive(Debug, Clone, Copy)]
pub enum Workload {
    /// Uniform random single-row deltas.
    UniformDeltas { updates: usize },
    /// Zipf-ish skewed deltas (hot rows).
    SkewedDeltas { updates: usize },
    /// Graph propagation rounds on a random graph.
    GraphRounds { nodes: usize, avg_degree: usize, rounds: usize },
}

/// Result of one workload run on one backend.
#[derive(Debug, Clone)]
pub struct AppRun {
    pub backend: &'static str,
    pub workload: String,
    pub requests: u64,
    pub batches: u64,
    pub rows_per_batch: f64,
    /// Modeled macro time to execute all batches (ns).
    pub modeled_ns: f64,
    /// Modeled energy (pJ).
    pub modeled_pj: f64,
    /// Wall-clock of the whole run (µs) — coordinator overhead view.
    pub wall_us: f64,
}

fn engine(rows: usize, q: usize, fast: bool) -> Result<UpdateEngine> {
    let mut cfg = EngineConfig::new(rows, q);
    cfg.seal_deadline = Duration::from_micros(200);
    if fast {
        UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
    } else {
        UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(DigitalBackend::new(plan.rows, plan.q)))
        })
    }
}

/// Run a workload against one backend.
pub fn run_workload(rows: usize, q: usize, fast: bool, w: Workload, seed: u64) -> Result<AppRun> {
    let e = engine(rows, q, fast)?;
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(seed);
    let label;
    match w {
        Workload::UniformDeltas { updates } => {
            label = format!("uniform-deltas({updates})");
            for _ in 0..updates {
                let row = rng.below(rows as u64) as usize;
                let v = rng.below(1 << q.min(16)) as u32;
                e.submit_blocking(UpdateRequest::add(row, v))?;
            }
        }
        Workload::SkewedDeltas { updates } => {
            label = format!("skewed-deltas({updates})");
            for _ in 0..updates {
                // 80% of traffic to 20% of rows.
                let hot = rng.chance(0.8);
                let span = if hot { rows / 5 } else { rows };
                let row = rng.below(span.max(1) as u64) as usize;
                let v = rng.below(1 << q.min(16)) as u32;
                e.submit_blocking(UpdateRequest::add(row, v))?;
            }
        }
        Workload::GraphRounds { nodes, avg_degree, rounds } => {
            label = format!("graph({nodes}n,{avg_degree}d,{rounds}r)");
            anyhow::ensure!(nodes <= rows, "graph larger than row space");
            let g = crate::apps::CsrGraph::random(nodes, avg_degree, seed);
            // Feature init.
            for n in 0..nodes {
                e.write(n, (n as u32 * 37 + 11) & crate::util::bits::mask(q))?;
            }
            for _ in 0..rounds {
                let snap = e.snapshot()?;
                for n in 0..nodes {
                    let m = (snap[n] >> 2) & crate::util::bits::mask(q);
                    if m == 0 {
                        continue;
                    }
                    for &t in g.out_neighbors(n) {
                        e.submit_blocking(UpdateRequest::add(t, m))?;
                    }
                }
                e.drain_all()?;
            }
        }
    }
    e.drain_all()?;
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;
    let s = e.stats();
    let run = AppRun {
        backend: s.backend,
        workload: label,
        requests: s.completed,
        batches: s.batches,
        rows_per_batch: s.rows_per_batch,
        modeled_ns: s.modeled_ns,
        modeled_pj: s.modeled_energy_pj,
        wall_us,
    };
    e.shutdown()?;
    Ok(run)
}

/// Run a workload on both backends and return (fast, digital).
pub fn compare(rows: usize, q: usize, w: Workload, seed: u64) -> Result<(AppRun, AppRun)> {
    let f = run_workload(rows, q, true, w, seed)?;
    let d = run_workload(rows, q, false, w, seed)?;
    Ok((f, d))
}

pub fn render(pairs: &[(AppRun, AppRun)]) -> String {
    let mut s = String::new();
    s.push_str("E-APP — application workloads through the coordinator (modeled macro time)\n");
    s.push_str(
        "workload              | backend          | batches | rows/batch | macro time | energy   | speedup\n",
    );
    s.push_str(
        "----------------------+------------------+---------+------------+------------+----------+--------\n",
    );
    for (f, d) in pairs {
        let speedup = d.modeled_ns / f.modeled_ns.max(1e-9);
        for r in [f, d] {
            s.push_str(&format!(
                "{:<21} | {:<16} | {:>7} | {:>10.1} | {:>7.2} µs | {:>5.1} nJ | {}\n",
                r.workload,
                r.backend,
                r.batches,
                r.rows_per_batch,
                r.modeled_ns / 1000.0,
                r.modeled_pj / 1000.0,
                if std::ptr::eq(r, f) {
                    format!("{speedup:>6.1}x")
                } else {
                    "   1.0x".to_string()
                }
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_beats_digital_on_modeled_time() {
        let (f, d) = compare(
            128,
            16,
            Workload::UniformDeltas { updates: 2000 },
            7,
        )
        .unwrap();
        assert_eq!(f.requests, 2000);
        assert_eq!(d.requests, 2000);
        assert!(
            f.modeled_ns < d.modeled_ns / 5.0,
            "fast {} ns vs digital {} ns",
            f.modeled_ns,
            d.modeled_ns
        );
    }

    #[test]
    fn skewed_coalesces_harder() {
        let (f_uni, _) = compare(128, 16, Workload::UniformDeltas { updates: 4000 }, 3).unwrap();
        let (f_skew, _) = compare(128, 16, Workload::SkewedDeltas { updates: 4000 }, 3).unwrap();
        // Skewed traffic touches fewer distinct rows per batch but the
        // same total requests — coalescing rate must be at least as high.
        let coal_uni = f_uni.requests as f64 / f_uni.rows_per_batch.max(1e-9) / f_uni.batches.max(1) as f64;
        let coal_skew = f_skew.requests as f64 / f_skew.rows_per_batch.max(1e-9) / f_skew.batches.max(1) as f64;
        assert!(coal_skew >= coal_uni * 0.8);
    }

    #[test]
    fn graph_workload_runs_on_both() {
        let (f, d) = compare(
            128,
            16,
            Workload::GraphRounds { nodes: 100, avg_degree: 4, rounds: 2 },
            11,
        )
        .unwrap();
        assert!(f.batches > 0 && d.batches > 0);
        assert!(f.modeled_ns < d.modeled_ns);
    }
}
