//! Experiment E-F14: **Fig. 14** — area breakdown of the 128-row FAST
//! die, plus the Section III.E overhead anchors: ~70% cell-level
//! overhead, ~10% shift-control overhead at 16 columns, ~41.7% total
//! macro overhead vs general-purpose SRAM.

use crate::energy::{AreaBreakdown, AreaModel};

#[derive(Debug, Clone)]
pub struct Fig14 {
    pub rows: usize,
    pub cols: usize,
    pub breakdown: AreaBreakdown,
    pub cell_overhead: f64,
    pub macro_overhead: f64,
    pub sram_macro_um2: f64,
}

pub fn run(rows: usize, cols: usize) -> Fig14 {
    let m = AreaModel::default();
    Fig14 {
        rows,
        cols,
        breakdown: m.fast_breakdown(rows, cols),
        cell_overhead: m.fast_cell() / m.p.area_cell_6t - 1.0,
        macro_overhead: m.macro_overhead(rows, cols),
        sram_macro_um2: m.sram_macro(rows, cols),
    }
}

pub fn render(f: &Fig14) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Fig. 14 — area breakdown, {}x{} FAST die\n",
        f.rows, f.cols
    ));
    for (name, pct) in f.breakdown.percentages() {
        let bar = "#".repeat((pct / 2.0).round() as usize);
        s.push_str(&format!("  {name:<26} {pct:>5.1}%  {bar}\n"));
    }
    s.push_str(&format!(
        "  total                      {:>8.0} µm²\n",
        f.breakdown.total
    ));
    s.push_str(&format!(
        "cell-level overhead : {:>5.1}%  (paper: ~70%)\n",
        100.0 * f.cell_overhead
    ));
    s.push_str(&format!(
        "macro-level overhead: {:>5.1}%  (paper: ~41.7%)\n",
        100.0 * f.macro_overhead
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_paper() {
        let f = run(128, 16);
        assert!((f.cell_overhead - 0.70).abs() < 0.01);
        assert!((f.macro_overhead - 0.417).abs() < 0.02);
        let shift_frac = f.breakdown.shift_ctrl / f.breakdown.cell_array;
        assert!((shift_frac - 0.10).abs() < 0.01);
    }

    #[test]
    fn percentages_sum_to_100() {
        let f = run(128, 16);
        let sum: f64 = f.breakdown.percentages().iter().map(|(_, p)| p).sum();
        assert!((sum - 100.0).abs() < 1e-9);
    }

    #[test]
    fn render_has_all_slices() {
        let s = render(&run(128, 16));
        assert!(s.contains("cell array"));
        assert!(s.contains("shift control"));
        assert!(s.contains("row ALUs"));
        assert!(s.contains("41.7%"));
    }
}
