//! Experiment E-F10: **Fig. 10** — per-word update energy (a) and
//! batch-update latency (b) versus bit width, FAST vs the digital
//! near-memory baseline.
//!
//! Paper claims to preserve:
//!  - (a) FAST wins on energy when rows sufficiently exceed the bit
//!    width; the advantage grows as rows/width grows (e.g. "4.4× with
//!    8-bit width and 512 rows").
//!  - (b) FAST latency depends on the bit width only; the baseline's
//!    depends on the row count — "hundreds of times speedup" for
//!    large row counts.

use crate::energy::{DigitalModel, FastModel};

/// One sweep point.
#[derive(Debug, Clone, Copy)]
pub struct Point {
    pub rows: usize,
    pub q: usize,
    /// Energy per word update (fJ).
    pub fast_energy_fj: f64,
    pub digital_energy_fj: f64,
    /// Batch-update latency for the whole array (ns).
    pub fast_latency_ns: f64,
    pub digital_latency_ns: f64,
}

impl Point {
    pub fn energy_ratio(&self) -> f64 {
        self.digital_energy_fj / self.fast_energy_fj
    }

    pub fn speedup(&self) -> f64 {
        self.digital_latency_ns / self.fast_latency_ns
    }
}

/// Sweep bit widths at fixed row counts.
pub fn sweep(row_counts: &[usize], widths: &[usize]) -> Vec<Point> {
    let fast = FastModel::default();
    let dig = DigitalModel::default();
    let mut out = Vec::with_capacity(row_counts.len() * widths.len());
    for &rows in row_counts {
        for &q in widths {
            let f_op = fast.calc_per_op(rows, q);
            let d_op = dig.calc_per_op(rows, q);
            let f_batch = fast.batch_op(rows, q);
            let d_batch = dig.batch_update(rows, q);
            out.push(Point {
                rows,
                q,
                fast_energy_fj: f_op.energy_fj,
                digital_energy_fj: d_op.energy_fj,
                fast_latency_ns: f_batch.latency_ns,
                digital_latency_ns: d_batch.latency_ns,
            });
        }
    }
    out
}

/// Default sweep matching the paper's axes.
pub fn run() -> Vec<Point> {
    sweep(&[128, 512], &[4, 8, 16, 32])
}

pub fn render(points: &[Point]) -> String {
    let mut s = String::new();
    s.push_str("Fig. 10(a) — energy per word update (fJ/OP)\n");
    s.push_str("rows  q  |  FAST fJ | Digital fJ |  ratio\n");
    s.push_str("---------+----------+------------+-------\n");
    for p in points {
        s.push_str(&format!(
            "{:>4} {:>3} | {:>8.1} | {:>10.1} | {:>5.1}x\n",
            p.rows,
            p.q,
            p.fast_energy_fj,
            p.digital_energy_fj,
            p.energy_ratio()
        ));
    }
    s.push_str("\nFig. 10(b) — whole-array batch update latency (ns)\n");
    s.push_str("rows  q  |  FAST ns | Digital ns | speedup\n");
    s.push_str("---------+----------+------------+--------\n");
    for p in points {
        s.push_str(&format!(
            "{:>4} {:>3} | {:>8.2} | {:>10.1} | {:>6.1}x\n",
            p.rows,
            p.q,
            p.fast_latency_ns,
            p.digital_latency_ns,
            p.speedup()
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_latency_depends_only_on_width() {
        let pts = sweep(&[128, 512], &[16]);
        // Same q ⇒ nearly same FAST batch latency (skew adds a few %)...
        let ratio = pts[1].fast_latency_ns / pts[0].fast_latency_ns;
        assert!(ratio < 1.1, "FAST latency grew {ratio}x with rows");
        // ...while the baseline scales ~4×.
        let dratio = pts[1].digital_latency_ns / pts[0].digital_latency_ns;
        assert!(dratio > 3.0, "digital latency ratio {dratio}");
    }

    #[test]
    fn speedup_grows_with_rows_over_width() {
        let pts = sweep(&[32, 128, 512], &[16]);
        assert!(pts[0].speedup() < pts[1].speedup());
        assert!(pts[1].speedup() < pts[2].speedup());
        // "hundreds of times" at 512 rows vs 16-bit.
        assert!(pts[2].speedup() > 100.0, "speedup {}", pts[2].speedup());
    }

    #[test]
    fn energy_advantage_grows_with_rows() {
        let pts = sweep(&[128, 512], &[8]);
        assert!(pts[1].energy_ratio() > pts[0].energy_ratio());
        // Paper's datapoint shape: >4× at (512 rows, 8-bit).
        assert!(pts[1].energy_ratio() > 4.0);
    }

    #[test]
    fn energy_advantage_shrinks_with_width_at_fixed_rows() {
        // FAST energy grows ~q² (q cycles × q cells) while the baseline
        // grows ~q, so the ratio must shrink as q rises.
        let pts = sweep(&[128], &[4, 8, 16, 32]);
        for w in pts.windows(2) {
            assert!(
                w[1].energy_ratio() < w[0].energy_ratio(),
                "ratio did not shrink: {} -> {}",
                w[0].energy_ratio(),
                w[1].energy_ratio()
            );
        }
    }

    #[test]
    fn table1_point_is_on_the_sweep() {
        let pts = sweep(&[128], &[16]);
        assert!((pts[0].energy_ratio() - 5.5).abs() < 0.3);
        assert!((pts[0].speedup() - 27.0).abs() < 2.0);
    }
}
