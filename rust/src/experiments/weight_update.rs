//! Experiment E-WU: the paper's headline evaluation — the 8-bit weight
//! update task in a VGG-7 framework, FAST vs the fully-digital
//! memory-computing-separated baseline (Section III: 96.0× speed,
//! 4.4× energy efficiency).
//!
//! One deterministic trainer trace (see [`crate::apps::trainer`]) is
//! replayed through the same coordinator on the word-fast FAST
//! backend, the bit-plane backend and the digital baseline; the run is
//! valid only if all three converge to bit-identical weights (and to
//! the host-semantics oracle), so the cost comparison can never quote
//! a backend that got fast by getting wrong. `fast train` renders this
//! table and asserts the repo bars (≥ 50× speed, ≥ 3× energy at the
//! 128×8 acceptance config).

use anyhow::ensure;

use crate::apps::trace::BackendKind;
use crate::apps::trainer::{
    self, TrainRun, TrainerConfig, MIN_ENERGY_EFF_X, MIN_SPEEDUP_X, PAPER_ENERGY_EFF_X,
    PAPER_SPEEDUP_X,
};
use crate::fastmem::Fidelity;
use crate::Result;

/// Cross-backend comparison on one recorded trainer trace.
#[derive(Debug, Clone)]
pub struct WeightUpdateReport {
    pub cfg: TrainerConfig,
    /// Word-fast FAST, bit-plane FAST, digital baseline — in that order.
    pub runs: Vec<TrainRun>,
    /// Modeled macro-time ratio digital / FAST (paper anchor: 96.0×).
    pub speedup: f64,
    /// Modeled energy ratio digital / FAST (paper anchor: 4.4×).
    pub energy_eff: f64,
}

impl WeightUpdateReport {
    /// Do the measured ratios clear the repo acceptance bars?
    pub fn passes_bars(&self) -> bool {
        self.speedup >= MIN_SPEEDUP_X && self.energy_eff >= MIN_ENERGY_EFF_X
    }
}

/// Record the config's VGG-7 trace once and replay it on every backend.
pub fn run(cfg: &TrainerConfig) -> Result<WeightUpdateReport> {
    let trace = trainer::record_trace(cfg)?;
    let reference = trace.reference_state();
    let mut runs = Vec::with_capacity(3);
    for kind in [
        BackendKind::Fast(Fidelity::WordFast),
        BackendKind::BitPlane,
        BackendKind::Digital,
    ] {
        let r = trainer::run_trace(cfg, &trace, kind)?;
        ensure!(
            r.final_state == reference,
            "{} diverged from host semantics on the recorded trace",
            r.backend
        );
        runs.push(r);
    }
    let fast = &runs[0];
    let digital = &runs[2];
    ensure!(
        fast.modeled_pj == runs[1].modeled_pj && fast.modeled_ns == runs[1].modeled_ns,
        "fidelity tiers must agree on modeled cost"
    );
    Ok(WeightUpdateReport {
        cfg: cfg.clone(),
        speedup: digital.modeled_ns / fast.modeled_ns.max(1e-12),
        energy_eff: digital.modeled_pj / fast.modeled_pj.max(1e-12),
        runs,
    })
}

/// Render the comparison table plus the paper-anchored ratio lines.
pub fn render(r: &WeightUpdateReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "E-WU — VGG-7 {q}-bit weight update, {rows} rows x {e} epochs x {st} steps \
         ({sh} shard{pl}, modeled macro cost per epoch)\n",
        q = r.cfg.q,
        rows = r.cfg.rows,
        e = r.cfg.epochs,
        st = r.cfg.steps_per_epoch,
        sh = r.cfg.shards,
        pl = if r.cfg.shards == 1 { "" } else { "s" },
    ));
    s.push_str(
        "backend              | updates | batches | rows/batch | time/epoch | energy/epoch\n",
    );
    s.push_str(
        "---------------------+---------+---------+------------+------------+-------------\n",
    );
    for run in &r.runs {
        s.push_str(&format!(
            "{:<20} | {:>7} | {:>7} | {:>10.1} | {:>7.3} µs | {:>8.2} nJ\n",
            run.backend,
            run.updates,
            run.batches,
            run.rows_per_batch,
            run.ns_per_epoch() / 1000.0,
            run.pj_per_epoch() / 1000.0,
        ));
    }
    let fast = &r.runs[0];
    if let Some(worst) = fast.commit_wall.iter().max_by_key(|s| s.p95_ns) {
        s.push_str(&format!(
            "\nlatency  : per-step submit→commit p50/p95/p99 = {}/{}/{} ns wall \
             (worst shard of {}, {} ticketed steps)\n",
            worst.p50_ns,
            worst.p95_ns,
            worst.p99_ns,
            fast.commit_wall.len(),
            fast.tickets,
        ));
    }
    s.push_str(&format!(
        "\nspeed    : {:>6.1}x vs digital (paper: {PAPER_SPEEDUP_X}x, repo bar: >= {MIN_SPEEDUP_X}x)\n",
        r.speedup
    ));
    s.push_str(&format!(
        "energy   : {:>6.1}x vs digital (paper: {PAPER_ENERGY_EFF_X}x, repo bar: >= {MIN_ENERGY_EFF_X}x)\n",
        r.energy_eff
    ));
    s.push_str(&format!(
        "verified : all backends bit-identical to host semantics ({} weights)\n",
        r.cfg.rows
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_covers_all_backends_and_passes_bars() {
        let mut cfg = TrainerConfig::vgg7(128, 8);
        cfg.epochs = 1;
        cfg.steps_per_epoch = 2;
        let r = run(&cfg).unwrap();
        assert_eq!(r.runs.len(), 3);
        assert!(r.passes_bars(), "speedup {:.1}x energy {:.1}x", r.speedup, r.energy_eff);
        let text = render(&r);
        assert!(text.contains("fast-behavioural"));
        assert!(text.contains("fast-bitplane"));
        assert!(text.contains("digital-baseline"));
        assert!(text.contains("repo bar"));
    }

    #[test]
    fn sharded_report_keeps_state_verified() {
        let mut cfg = TrainerConfig::vgg7(128, 8);
        cfg.epochs = 1;
        cfg.steps_per_epoch = 2;
        cfg.shards = 4;
        let r = run(&cfg).unwrap();
        // All runs verified against the oracle inside run(); the FAST
        // runs must also agree with each other on modeled cost.
        assert_eq!(r.runs[0].modeled_pj, r.runs[1].modeled_pj);
    }

    /// PR-4 acceptance: the ticketed workload is bit-identical to its
    /// flush-based equivalent — `run()` already refuses to report
    /// unless every backend (fast-word, bitplane, digital) matches the
    /// host oracle on the recorded trace; here that is exercised at 1
    /// and 4 shards, and the ticket path must have carried the run
    /// (per-step acks on every shard, latency histograms populated).
    #[test]
    fn ticketed_workload_matches_oracle_at_one_and_four_shards() {
        for shards in [1usize, 4] {
            let mut cfg = TrainerConfig::vgg7(128, 8);
            cfg.epochs = 1;
            cfg.steps_per_epoch = 2;
            cfg.shards = shards;
            let r = run(&cfg).unwrap();
            let steps = (cfg.epochs * cfg.steps_per_epoch) as u64;
            for run in &r.runs {
                assert_eq!(
                    run.tickets,
                    steps * shards as u64,
                    "{} at {shards} shards must ack per shard per step",
                    run.backend
                );
                assert_eq!(run.commit_wall.len(), shards);
                assert!(run.commit_wall.iter().all(|s| s.count == steps));
            }
            let text = render(&r);
            assert!(text.contains("submit→commit"), "render surfaces commit latency");
        }
    }
}
