//! Experiment E-F11: **Fig. 11** — batch-update latency and
//! area-normalized energy efficiency versus row count at several bit
//! widths ("normalized into the same area").
//!
//! Shape to preserve: latency of the FAST batch update is flat in the
//! row count (vs linear for the baseline), and the area-normalized
//! efficiency advantage grows with rows and shrinks with bit width.

use crate::energy::{AreaModel, DigitalModel, FastModel};

#[derive(Debug, Clone, Copy)]
pub struct Point {
    pub rows: usize,
    pub q: usize,
    /// Whole-array batch-update latency (ns).
    pub fast_latency_ns: f64,
    pub digital_latency_ns: f64,
    /// Energy efficiency in OPs per nJ.
    pub fast_ops_per_nj: f64,
    pub digital_ops_per_nj: f64,
    /// Same, normalized by macro area (OPs / nJ / mm² × 1e-6 —
    /// arbitrary consistent unit, FAST divided by its area overhead).
    pub fast_ops_per_nj_per_area: f64,
    pub digital_ops_per_nj_per_area: f64,
}

impl Point {
    pub fn normalized_advantage(&self) -> f64 {
        self.fast_ops_per_nj_per_area / self.digital_ops_per_nj_per_area
    }
}

pub fn sweep(row_counts: &[usize], widths: &[usize]) -> Vec<Point> {
    let fast = FastModel::default();
    let dig = DigitalModel::default();
    let area = AreaModel::default();
    let mut out = Vec::new();
    for &q in widths {
        for &rows in row_counts {
            let f_batch = fast.batch_op(rows, q);
            let d_batch = dig.batch_update(rows, q);
            let f_eff = f_batch.ops_per_nj(rows as u64);
            let d_eff = d_batch.ops_per_nj(rows as u64);
            let f_area = area.fast_macro(rows, q);
            let d_area = area.sram_macro(rows, q);
            out.push(Point {
                rows,
                q,
                fast_latency_ns: f_batch.latency_ns,
                digital_latency_ns: d_batch.latency_ns,
                fast_ops_per_nj: f_eff,
                digital_ops_per_nj: d_eff,
                fast_ops_per_nj_per_area: f_eff / f_area,
                digital_ops_per_nj_per_area: d_eff / d_area,
            });
        }
    }
    out
}

/// Default sweep matching the paper's axes.
pub fn run() -> Vec<Point> {
    sweep(&[32, 64, 128, 256, 512, 1024], &[8, 16, 32])
}

pub fn render(points: &[Point]) -> String {
    let mut s = String::new();
    s.push_str("Fig. 11 — batch-update latency + area-normalized efficiency\n");
    s.push_str(
        "   q rows | FAST ns | Dig ns  | FAST OP/nJ | Dig OP/nJ | norm adv\n",
    );
    s.push_str(
        "----------+---------+---------+------------+-----------+---------\n",
    );
    for p in points {
        s.push_str(&format!(
            "{:>4} {:>4} | {:>7.2} | {:>7.1} | {:>10.1} | {:>9.1} | {:>7.2}x\n",
            p.q,
            p.rows,
            p.fast_latency_ns,
            p.digital_latency_ns,
            p.fast_ops_per_nj,
            p.digital_ops_per_nj,
            p.normalized_advantage()
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_batch_latency_flat_in_rows() {
        let pts = sweep(&[32, 1024], &[16]);
        let ratio = pts[1].fast_latency_ns / pts[0].fast_latency_ns;
        assert!(ratio < 1.1, "FAST latency must be ~flat in rows, got {ratio}x");
        let dratio = pts[1].digital_latency_ns / pts[0].digital_latency_ns;
        assert!(dratio > 20.0, "digital must scale with rows, got {dratio}x");
    }

    #[test]
    fn normalized_advantage_grows_with_rows() {
        let pts = sweep(&[64, 256, 1024], &[16]);
        assert!(pts[0].normalized_advantage() < pts[1].normalized_advantage());
        assert!(pts[1].normalized_advantage() < pts[2].normalized_advantage());
    }

    #[test]
    fn advantage_shrinks_with_width() {
        let narrow = sweep(&[512], &[8]);
        let wide = sweep(&[512], &[32]);
        assert!(narrow[0].normalized_advantage() > wide[0].normalized_advantage());
    }

    #[test]
    fn area_normalization_costs_fast_roughly_the_overhead() {
        let pts = sweep(&[128], &[16]);
        let p = pts[0];
        let raw_adv = p.fast_ops_per_nj / p.digital_ops_per_nj;
        let norm_adv = p.normalized_advantage();
        // The normalized advantage must be lower by about the ~1.4x
        // area overhead.
        let penalty = raw_adv / norm_adv;
        assert!((1.3..1.6).contains(&penalty), "area penalty {penalty}");
    }
}
