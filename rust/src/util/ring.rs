//! Bounded lock-free MPSC ring — the shard admission queue.
//!
//! Replaces the `std::sync::mpsc::sync_channel` each engine shard used
//! for admission. `sync_channel` takes a mutex on every send *and*
//! every recv, so under multi-producer load the producers serialize on
//! the queue lock before they ever reach the shard worker — exactly
//! the futex-wait pileup the ROADMAP's profiling notes describe. This
//! ring keeps the hot path to a handful of atomics:
//!
//! - **Slot sequence numbers** (Vyukov's bounded-queue scheme): slot
//!   `i` starts with `seq == i`; a producer that claimed position `t`
//!   publishes by storing `seq = t + 1`, and the consumer at position
//!   `h` consumes when it reads `seq == h + 1`, releasing the slot for
//!   the next lap with `seq = h + buf_len`. The sequence is both the
//!   "is this slot ready" flag and the ABA guard.
//! - **Claim by CAS on `tail`**, admission-checked first: a producer
//!   loads `tail` then `head` and refuses (`Full`) when
//!   `tail - head >= cap`. The CAS serializes claims and a stale
//!   `head` can only *underestimate* free space, so occupancy never
//!   exceeds `cap` — `len()` is therefore a safe source for the
//!   engine's queue-depth / high-water gauges (the old raise-before-
//!   send gauge could transiently overcount past `cap` on a rejected
//!   submit).
//! - **Spin-then-park** blocking: `send`/`recv` spin a short budget of
//!   `spin_loop` hints, then register as a sleeper on an eventcount
//!   (sleeper counter + `Mutex<()>` + `Condvar`, touched only on the
//!   slow path) and wait. The waker checks the sleeper count *after*
//!   its publish and brackets `notify_all` with the mutex, which —
//!   with SeqCst on the sleeper counter — makes lost wakeups
//!   impossible; a bounded `wait_timeout` backstops the reasoning
//!   anyway. `send` reports how many spins/parks it took so the
//!   engine can export contention counters (`submit_spins`,
//!   `park_events`) without a profiler.
//!
//! Disconnect semantics mirror `mpsc`: when every `RingSender` is
//! dropped, `recv` drains what's buffered and then reports
//! `Disconnected`; when the `RingReceiver` is dropped, sends fail
//! (parked producers are woken) and buffered items are dropped with
//! the shared state.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Spins a producer/consumer burns before parking. Small: if the
/// queue stays full/empty for longer than a few dozen probes, the
/// other side is busy for a "long" time (an apply, an fsync) and
/// sleeping is cheaper than burning the core.
const SPIN_LIMIT: u32 = 64;

/// Parked waits are bounded so a (theoretical) missed wake degrades
/// to a poll, never a hang.
const PARK_BACKSTOP: Duration = Duration::from_millis(5);

/// How much slow-path work a blocking `send` performed, for the
/// engine's contention counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SendReport {
    /// `spin_loop` probes taken while the ring was full.
    pub spins: u64,
    /// Times the producer gave up spinning and parked on the
    /// eventcount.
    pub parks: u64,
}

/// `try_send` failure: the value is handed back in both cases.
#[derive(Debug)]
pub enum TrySendError<T> {
    /// Ring at capacity — the caller's typed-backpressure case.
    Full(T),
    /// Receiver dropped; the value can never be consumed.
    Disconnected(T),
}

/// Blocking `send` failure: receiver gone.
#[derive(Debug)]
pub struct SendError<T>(pub T);

/// `try_recv` failure.
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

/// `recv_timeout` failure.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    Timeout,
    Disconnected,
}

/// `recv` failure: all senders gone and the ring is drained.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// One side of the eventcount: sleeper count + mutex/condvar used
/// only when somebody actually has to sleep.
struct Park {
    sleepers: AtomicUsize,
    m: Mutex<()>,
    cv: Condvar,
}

impl Park {
    fn new() -> Self {
        Park { sleepers: AtomicUsize::new(0), m: Mutex::new(()), cv: Condvar::new() }
    }

    /// Wake all sleepers if there are any. The empty lock/unlock
    /// bracket orders the notify against a sleeper that has
    /// registered but not yet started waiting.
    fn wake(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            drop(self.m.lock().expect("ring park mutex poisoned"));
            self.cv.notify_all();
        }
    }
}

struct Shared<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    cap: usize,
    /// Next position a producer will claim. Producers CAS this.
    tail: AtomicUsize,
    /// Next position the consumer will take. Consumer-only writes.
    head: AtomicUsize,
    senders: AtomicUsize,
    rx_alive: AtomicBool,
    /// Producers park here when the ring is full.
    not_full: Park,
    /// The consumer parks here when the ring is empty.
    not_empty: Park,
}

// Slots hold `UnsafeCell`s but access is disciplined by the sequence
// protocol: a slot's value is written by exactly the producer that
// claimed its position and read by the consumer only after the
// producer's Release store of the matching sequence.
unsafe impl<T: Send> Send for Shared<T> {}
unsafe impl<T: Send> Sync for Shared<T> {}

impl<T> Shared<T> {
    fn len(&self) -> usize {
        // Loading head after tail can only shrink the answer; the
        // admission check keeps tail - head <= cap, so the result is
        // in [0, cap] whenever the loads are close in time.
        let tail = self.tail.load(Ordering::Acquire);
        let head = self.head.load(Ordering::Acquire);
        tail.wrapping_sub(head).min(self.cap)
    }

    fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        if !self.rx_alive.load(Ordering::Acquire) {
            return Err(TrySendError::Disconnected(v));
        }
        let mut tail = self.tail.load(Ordering::Relaxed);
        loop {
            let head = self.head.load(Ordering::Acquire);
            if tail.wrapping_sub(head) >= self.cap {
                // A racing consumer may free a slot right after this
                // load — that's fine: Full is allowed to be
                // conservative, overshooting cap is not.
                return Err(TrySendError::Full(v));
            }
            match self.tail.compare_exchange_weak(
                tail,
                tail.wrapping_add(1),
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    let slot = &self.buf[tail & self.mask];
                    unsafe { (*slot.val.get()).write(v) };
                    slot.seq.store(tail.wrapping_add(1), Ordering::Release);
                    self.not_empty.wake();
                    return Ok(());
                }
                Err(now) => tail = now,
            }
        }
    }

    /// Consumer-only. Returns `Empty` both when the ring is truly
    /// empty and when the head slot is claimed but not yet published
    /// (the producer is between its CAS and its seq store).
    fn try_recv(&self) -> Result<T, TryRecvError> {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.buf[head & self.mask];
        if slot.seq.load(Ordering::Acquire) == head.wrapping_add(1) {
            let v = unsafe { (*slot.val.get()).assume_init_read() };
            // Release the slot for the producers' next lap…
            slot.seq.store(head.wrapping_add(self.buf.len()), Ordering::Release);
            // …and the position for the admission check.
            self.head.store(head.wrapping_add(1), Ordering::Release);
            self.not_full.wake();
            return Ok(v);
        }
        if self.senders.load(Ordering::SeqCst) == 0
            && self.tail.load(Ordering::Acquire) == head
        {
            return Err(TryRecvError::Disconnected);
        }
        Err(TryRecvError::Empty)
    }
}

impl<T> Drop for Shared<T> {
    fn drop(&mut self) {
        // Both sides are gone: every claimed slot is also published
        // (no producer can be mid-push), so drop what was buffered.
        let head = *self.head.get_mut();
        let tail = *self.tail.get_mut();
        let mut pos = head;
        while pos != tail {
            let slot = &mut self.buf[pos & self.mask];
            if *slot.seq.get_mut() == pos.wrapping_add(1) {
                unsafe { (*slot.val.get()).assume_init_drop() };
            }
            pos = pos.wrapping_add(1);
        }
    }
}

/// Cloneable producer handle.
pub struct RingSender<T> {
    shared: Arc<Shared<T>>,
}

/// Single-consumer handle (not cloneable).
pub struct RingReceiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create a bounded MPSC ring admitting at most `cap` items
/// (`cap >= 1`; the backing buffer is the next power of two).
pub fn channel<T>(cap: usize) -> (RingSender<T>, RingReceiver<T>) {
    assert!(cap >= 1, "ring capacity must be at least 1");
    let buf_len = cap.next_power_of_two();
    let buf: Box<[Slot<T>]> = (0..buf_len)
        .map(|i| Slot { seq: AtomicUsize::new(i), val: UnsafeCell::new(MaybeUninit::uninit()) })
        .collect();
    let shared = Arc::new(Shared {
        buf,
        mask: buf_len - 1,
        cap,
        tail: AtomicUsize::new(0),
        head: AtomicUsize::new(0),
        senders: AtomicUsize::new(1),
        rx_alive: AtomicBool::new(true),
        not_full: Park::new(),
        not_empty: Park::new(),
    });
    (RingSender { shared: Arc::clone(&shared) }, RingReceiver { shared })
}

impl<T> Clone for RingSender<T> {
    fn clone(&self) -> Self {
        self.shared.senders.fetch_add(1, Ordering::SeqCst);
        RingSender { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Drop for RingSender<T> {
    fn drop(&mut self) {
        if self.shared.senders.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Last sender: a blocked consumer must wake to observe
            // the disconnect.
            self.shared.not_empty.wake();
        }
    }
}

impl<T> Drop for RingReceiver<T> {
    fn drop(&mut self) {
        self.shared.rx_alive.store(false, Ordering::Release);
        // Parked producers must wake to observe the disconnect.
        self.shared.not_full.wake();
    }
}

impl<T> RingSender<T> {
    /// Non-blocking send — the engine's typed-backpressure path.
    pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        self.shared.try_send(v)
    }

    /// Blocking send: spin a short budget, then park until the
    /// consumer frees a slot. Reports the slow-path work done so the
    /// caller can account contention.
    pub fn send(&self, v: T) -> Result<SendReport, SendError<T>> {
        let mut report = SendReport::default();
        let mut pending = v;
        let mut spin_budget = SPIN_LIMIT;
        loop {
            match self.shared.try_send(pending) {
                Ok(()) => return Ok(report),
                Err(TrySendError::Disconnected(v)) => return Err(SendError(v)),
                Err(TrySendError::Full(v)) => {
                    pending = v;
                    if spin_budget > 0 {
                        spin_budget -= 1;
                        report.spins += 1;
                        std::hint::spin_loop();
                        continue;
                    }
                    report.parks += 1;
                    let s = &self.shared;
                    let guard = s.not_full.m.lock().expect("ring park mutex poisoned");
                    s.not_full.sleepers.fetch_add(1, Ordering::SeqCst);
                    // Re-check under sleeper registration: a consumer
                    // that freed a slot before seeing us registered
                    // is caught here instead of being waited on.
                    let still_full = s.len() >= s.cap && s.rx_alive.load(Ordering::Acquire);
                    if still_full {
                        let (guard, _) = s
                            .not_full
                            .cv
                            .wait_timeout(guard, PARK_BACKSTOP)
                            .expect("ring park mutex poisoned");
                        drop(guard);
                    } else {
                        drop(guard);
                    }
                    s.not_full.sleepers.fetch_sub(1, Ordering::SeqCst);
                    spin_budget = SPIN_LIMIT;
                }
            }
        }
    }

    /// Items currently admitted (≤ `cap` by construction) — the
    /// engine's queue-depth gauge source.
    pub fn len(&self) -> usize {
        self.shared.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.shared.cap
    }

    /// True once the receiver is gone (the worker exited).
    pub fn is_disconnected(&self) -> bool {
        !self.shared.rx_alive.load(Ordering::Acquire)
    }
}

impl<T> RingReceiver<T> {
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        self.shared.try_recv()
    }

    /// Blocking receive; `Err(RecvError)` once every sender is gone
    /// and the buffer is drained.
    pub fn recv(&self) -> Result<T, RecvError> {
        loop {
            match self.recv_deadline(None) {
                Ok(v) => return Ok(v),
                Err(RecvTimeoutError::Disconnected) => return Err(RecvError),
                Err(RecvTimeoutError::Timeout) => unreachable!("no deadline"),
            }
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        self.recv_deadline(Some(Instant::now() + timeout))
    }

    fn recv_deadline(&self, deadline: Option<Instant>) -> Result<T, RecvTimeoutError> {
        let s = &self.shared;
        let mut spin_budget = SPIN_LIMIT;
        loop {
            match s.try_recv() {
                Ok(v) => return Ok(v),
                Err(TryRecvError::Disconnected) => return Err(RecvTimeoutError::Disconnected),
                Err(TryRecvError::Empty) => {}
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return Err(RecvTimeoutError::Timeout);
                }
            }
            if spin_budget > 0 {
                spin_budget -= 1;
                std::hint::spin_loop();
                continue;
            }
            let guard = s.not_empty.m.lock().expect("ring park mutex poisoned");
            s.not_empty.sleepers.fetch_add(1, Ordering::SeqCst);
            let empty = s.tail.load(Ordering::Acquire) == s.head.load(Ordering::Acquire)
                && s.senders.load(Ordering::SeqCst) > 0;
            if empty {
                let wait = match deadline {
                    Some(d) => d.saturating_duration_since(Instant::now()).min(PARK_BACKSTOP),
                    None => PARK_BACKSTOP,
                };
                let (guard, _) = s
                    .not_empty
                    .cv
                    .wait_timeout(guard, wait)
                    .expect("ring park mutex poisoned");
                drop(guard);
            } else {
                drop(guard);
            }
            s.not_empty.sleepers.fetch_sub(1, Ordering::SeqCst);
            spin_budget = SPIN_LIMIT;
        }
    }

    /// Items currently buffered.
    pub fn len(&self) -> usize {
        self.shared.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicU64;
    use std::thread;

    #[test]
    fn fifo_single_producer() {
        let (tx, rx) = channel::<u32>(4);
        for i in 0..4 {
            tx.try_send(i).unwrap();
        }
        assert!(matches!(tx.try_send(99), Err(TrySendError::Full(99))));
        assert_eq!(tx.len(), 4);
        for i in 0..4 {
            assert_eq!(rx.try_recv().unwrap(), i);
        }
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        assert_eq!(tx.len(), 0);
    }

    #[test]
    fn capacity_is_exact_not_power_of_two() {
        // cap 5 rides an 8-slot buffer but must admit exactly 5.
        let (tx, rx) = channel::<u32>(5);
        for i in 0..5 {
            tx.try_send(i).unwrap();
        }
        assert!(matches!(tx.try_send(5), Err(TrySendError::Full(5))));
        rx.try_recv().unwrap();
        tx.try_send(5).unwrap();
        assert_eq!(tx.len(), 5);
    }

    #[test]
    fn wraps_many_laps() {
        let (tx, rx) = channel::<usize>(3);
        for i in 0..1000 {
            tx.try_send(i).unwrap();
            assert_eq!(rx.try_recv().unwrap(), i);
        }
    }

    #[test]
    fn recv_disconnects_after_drain() {
        let (tx, rx) = channel::<u32>(4);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        drop(tx);
        assert_eq!(rx.try_recv().unwrap(), 1);
        assert_eq!(rx.try_recv().unwrap(), 2);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = channel::<u32>(4);
        drop(rx);
        assert!(matches!(tx.try_send(7), Err(TrySendError::Disconnected(7))));
        assert!(matches!(tx.send(8), Err(SendError(8))));
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = channel::<u32>(4);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvTimeoutError::Timeout)
        );
        tx.try_send(42).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(100)).unwrap(), 42);
    }

    #[test]
    fn blocking_send_parks_until_consumer_frees_a_slot() {
        let (tx, rx) = channel::<u32>(1);
        tx.try_send(0).unwrap();
        let t = thread::spawn(move || {
            let report = tx.send(1).unwrap();
            (tx, report)
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.try_recv().unwrap(), 0);
        let (_tx, report) = t.join().unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 1);
        // Full for ~20ms: the producer must have done slow-path work.
        assert!(report.spins + report.parks > 0);
    }

    #[test]
    fn drops_buffered_items_exactly_once() {
        static DROPS: AtomicU64 = AtomicU64::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let (tx, rx) = channel::<D>(8);
        for _ in 0..5 {
            tx.try_send(D).unwrap();
        }
        drop(rx.try_recv().unwrap()); // 1 consumed drop
        drop(tx);
        drop(rx); // 4 buffered drops
        assert_eq!(DROPS.load(Ordering::SeqCst), 5);
    }

    /// Multi-producer stress: per-producer FIFO, no loss, no
    /// duplication, across repeated full/empty transitions.
    #[test]
    fn multi_producer_fifo_no_loss_no_dup() {
        for &producers in &[1usize, 2, 4, 8] {
            let per = 2000usize;
            let (tx, rx) = channel::<(usize, usize)>(8); // tiny: forces full/empty churn
            let handles: Vec<_> = (0..producers)
                .map(|p| {
                    let tx = tx.clone();
                    thread::spawn(move || {
                        let mut rng = Rng::new(0x5eed ^ p as u64);
                        for i in 0..per {
                            tx.send((p, i)).unwrap();
                            if rng.next_u64() % 7 == 0 {
                                thread::yield_now();
                            }
                        }
                    })
                })
                .collect();
            drop(tx);
            let mut next = vec![0usize; producers];
            let mut total = 0usize;
            loop {
                match rx.recv() {
                    Ok((p, i)) => {
                        assert_eq!(i, next[p], "producer {p} out of order");
                        next[p] += 1;
                        total += 1;
                    }
                    Err(RecvError) => break,
                }
            }
            assert_eq!(total, producers * per);
            for h in handles {
                h.join().unwrap();
            }
        }
    }

    /// Parked producers racing a receiver drop must all disconnect,
    /// never hang.
    #[test]
    fn parked_producers_survive_racing_shutdown() {
        for trial in 0..20u64 {
            let (tx, rx) = channel::<u64>(1);
            tx.try_send(0).unwrap(); // full: all senders will park
            let handles: Vec<_> = (0..4)
                .map(|p| {
                    let tx = tx.clone();
                    thread::spawn(move || tx.send(trial * 10 + p))
                })
                .collect();
            thread::sleep(Duration::from_micros(50 * (trial % 5)));
            drop(rx);
            for h in handles {
                // Each blocked sender either slipped in before the
                // drop (impossible here: cap 1, never drained) or
                // gets its value back.
                assert!(h.join().unwrap().is_err());
            }
        }
    }
}
