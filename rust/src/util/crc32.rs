//! Table-driven CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320)
//! — no dependencies, the checksum behind the durability subsystem's
//! WAL frames and snapshot files, and the serve protocol's optional
//! `DIGEST CRC` line.
//!
//! The 256-entry table is computed at compile time (`const fn`), so
//! there is no runtime initialization to race on. The streaming
//! [`Crc32`] builder and the one-shot [`crc32`] function are the same
//! algorithm; the round-trip property (any split of the input updates
//! to the same value) is quickprop-tested below.

/// Compile-time CRC-32 table for the reflected IEEE polynomial.
const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    Crc32::new().update(data).finish()
}

/// Streaming CRC-32 state: `new() → update(..) → … → finish()`.
/// `update` takes and returns the state by value so call sites can
/// chain without a mutable binding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    #[must_use]
    pub fn update(mut self, data: &[u8]) -> Self {
        for &b in data {
            let idx = ((self.state ^ b as u32) & 0xFF) as usize;
            self.state = TABLE[idx] ^ (self.state >> 8);
        }
        self
    }

    pub fn finish(self) -> u32 {
        !self.state
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{check, Gen};

    #[test]
    fn known_answer_vectors() {
        // The standard check value for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
        assert_eq!(crc32(&[0xFFu8; 32]), 0xFF6C_AB0B);
    }

    fn gen_bytes(g: &mut Gen) -> Vec<u8> {
        g.vec_of(64, |g| g.u32_below(256) as u8)
    }

    #[test]
    fn prop_streaming_equals_one_shot() {
        // Splitting the input at any point must not change the CRC.
        check("crc32 split invariance", 300, |g| {
            let data = gen_bytes(g);
            let cut = g.usize_in(0, data.len());
            let split = Crc32::new()
                .update(&data[..cut])
                .update(&data[cut..])
                .finish();
            split == crc32(&data)
        });
    }

    #[test]
    fn prop_detects_single_bit_flips() {
        // CRC-32 detects every single-bit error by construction.
        check("crc32 single-bit flip", 300, |g| {
            let mut data = gen_bytes(g);
            if data.is_empty() {
                data.push(g.u32_below(256) as u8);
            }
            let before = crc32(&data);
            let byte = g.usize_in(0, data.len() - 1);
            let bit = g.usize_in(0, 7);
            data[byte] ^= 1 << bit;
            crc32(&data) != before
        });
    }

    #[test]
    fn prop_byte_order_matters() {
        check("crc32 discriminates order", 200, |g| {
            let a = g.u32_below(256) as u8;
            let b = g.u32_below(256) as u8;
            // Equal bytes collide trivially; distinct ones must not.
            a == b || crc32(&[a, b]) != crc32(&[b, a])
        });
    }
}
