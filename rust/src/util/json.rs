//! Minimal JSON parser for the artifact manifest.
//!
//! `serde` is not in the offline vendor set (see DESIGN.md §7), and the
//! only JSON we consume is `artifacts/manifest.json`, which we also
//! author — so a small, strict, well-tested recursive-descent parser is
//! the right tool. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (not needed for the manifest).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let ch = char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u escape unsupported"))?;
                            out.push(ch);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(_) => {
                    // Copy one UTF-8 scalar (multi-byte safe).
                    let start = self.i;
                    let s = &self.b[start..];
                    let len = utf8_len(s[0]);
                    if len == 0 || start + len > self.b.len() {
                        return Err(self.err("invalid utf-8"));
                    }
                    let chunk = std::str::from_utf8(&s[..len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
        assert_eq!(Json::parse("128").unwrap().as_usize(), Some(128));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "format": "hlo-text", "return_tuple": true,
          "artifacts": [
            {"name": "fast_add_128x16", "op": "add", "rows": 128, "q": 16,
             "inputs": [["u32", [128]], ["u32", [128]]],
             "outputs": [["u32", [128]]],
             "file": "fast_add_128x16.hlo.txt", "sha256": "ab"}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("rows").unwrap().as_usize(), Some(128));
    }
}
