//! Word/bit helpers shared by the behavioural array model, the
//! coordinator and the tests. Semantics identical to
//! `python/compile/kernels/ref.py` (one semantics, three impls).

/// All-ones mask for a q-bit word. Panics if q ∉ [1, 32].
#[inline]
pub fn mask(q: usize) -> u32 {
    assert!((1..=32).contains(&q), "bit width q must be in [1,32], got {q}");
    if q == 32 {
        u32::MAX
    } else {
        (1u32 << q) - 1
    }
}

/// (a + b) mod 2^q.
#[inline]
pub fn add_mod(a: u32, b: u32, q: usize) -> u32 {
    a.wrapping_add(b) & mask(q)
}

/// (a - b) mod 2^q.
#[inline]
pub fn sub_mod(a: u32, b: u32, q: usize) -> u32 {
    a.wrapping_sub(b) & mask(q)
}

/// Unpack a word into q bits, LSB first (col 0 = cell next to the ALU).
pub fn unpack(word: u32, q: usize) -> Vec<u8> {
    (0..q).map(|t| ((word >> t) & 1) as u8).collect()
}

/// Pack LSB-first bits back into a word.
pub fn pack(bits: &[u8]) -> u32 {
    assert!(bits.len() <= 32);
    bits.iter()
        .enumerate()
        .fold(0u32, |acc, (t, &b)| acc | ((b as u32 & 1) << t))
}

/// 1-bit full adder: returns (sum, carry_out).
#[inline]
pub fn full_adder(a: u8, b: u8, cin: u8) -> (u8, u8) {
    let s = a ^ b ^ cin;
    let c = (a & b) | (a & cin) | (b & cin);
    (s, c)
}

/// In-place 64×64 bit-matrix transpose (recursive block swap, the
/// classic Hacker's-Delight schedule adapted to LSB-first columns):
/// after the call, bit `r` of `a[c]` equals bit `c` of the old `a[r]`.
///
/// This is the workhorse of the bit-plane (bit-sliced) fidelity tier:
/// one call re-slices 64 row words into 64 bitplane lanes in ~6·32
/// word ops instead of 64·64 single-bit moves.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: usize = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            // Swap the j-bit between row index and column index:
            // M[k][p+j] <-> M[k+j][p] for every column p with p&j == 0.
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_values() {
        assert_eq!(mask(1), 1);
        assert_eq!(mask(8), 0xFF);
        assert_eq!(mask(16), 0xFFFF);
        assert_eq!(mask(32), u32::MAX);
    }

    #[test]
    #[should_panic]
    fn mask_rejects_zero() {
        mask(0);
    }

    #[test]
    #[should_panic]
    fn mask_rejects_33() {
        mask(33);
    }

    #[test]
    fn add_sub_wrap() {
        assert_eq!(add_mod(0xFFFF, 1, 16), 0);
        assert_eq!(sub_mod(0, 1, 16), 0xFFFF);
        assert_eq!(add_mod(200, 100, 8), 44); // 300 mod 256
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for &w in &[0u32, 1, 0xAB, 0xFFFF, 0xDEADBEEF] {
            for q in [8usize, 16, 32] {
                let bits = unpack(w, q);
                assert_eq!(bits.len(), q);
                assert_eq!(pack(&bits), w & mask(q));
            }
        }
    }

    #[test]
    fn transpose64_matches_naive_definition() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4242);
        for _ in 0..20 {
            let mut a = [0u64; 64];
            for w in a.iter_mut() {
                *w = rng.next_u64();
            }
            let orig = a;
            transpose64(&mut a);
            for r in 0..64 {
                for c in 0..64 {
                    assert_eq!(
                        (a[c] >> r) & 1,
                        (orig[r] >> c) & 1,
                        "bit ({r},{c}) after transpose"
                    );
                }
            }
            // Involution: transposing twice restores the original.
            transpose64(&mut a);
            assert_eq!(a, orig);
        }
    }

    #[test]
    fn transpose64_identity_and_single_bit() {
        let mut a = [0u64; 64];
        transpose64(&mut a);
        assert_eq!(a, [0u64; 64]);
        let mut b = [0u64; 64];
        b[3] = 1 << 17; // M[3][17]
        transpose64(&mut b);
        assert_eq!(b[17], 1 << 3); // -> M[17][3]
        b[17] = 0;
        assert_eq!(b, [0u64; 64]);
    }

    #[test]
    fn full_adder_truth_table() {
        // (a, b, cin) -> (sum, carry)
        let cases = [
            (0, 0, 0, 0, 0),
            (0, 0, 1, 1, 0),
            (0, 1, 0, 1, 0),
            (0, 1, 1, 0, 1),
            (1, 0, 0, 1, 0),
            (1, 0, 1, 0, 1),
            (1, 1, 0, 0, 1),
            (1, 1, 1, 1, 1),
        ];
        for (a, b, c, s, co) in cases {
            assert_eq!(full_adder(a, b, c), (s, co), "a={a} b={b} c={c}");
        }
    }
}
