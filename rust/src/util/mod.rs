//! Shared utilities: JSON parsing, deterministic PRNG, property testing,
//! bit/word helpers, timing helpers.

pub mod bits;
pub mod crc32;
pub mod json;
pub mod quickprop;
pub mod ring;
pub mod rng;
pub mod stats;
