//! Mini property-testing framework (proptest is not in the offline
//! vendor set — DESIGN.md §7). Provides seeded random-case generation
//! with greedy input shrinking for the coordinator/array invariant tests.
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath flags
//! on this image — the property itself runs in unit tests below):
//! ```no_run
//! use fast_sram::util::bits::add_mod;
//! use fast_sram::util::quickprop::{check, Gen};
//!
//! check("add commutes", 200, |g: &mut Gen| {
//!     let a = g.u32_below(1 << 16);
//!     let b = g.u32_below(1 << 16);
//!     add_mod(a, b, 16) == add_mod(b, a, 16)
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to each property invocation. Records the draws
/// so failures can be replayed/shrunk.
pub struct Gen {
    rng: Rng,
    /// Scale factor in (0, 1]; shrinking re-runs with smaller scales so
    /// size-like draws get smaller.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Gen { rng: Rng::new(seed), scale }
    }

    /// Uniform u32 in [0, n), scaled down during shrinking.
    pub fn u32_below(&mut self, n: u32) -> u32 {
        let eff = ((n as f64 * self.scale).ceil() as u64).clamp(1, n as u64);
        self.rng.below(eff) as u32
    }

    /// Uniform usize in [lo, hi], scaled toward lo during shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        let eff = ((span as f64 * self.scale).ceil() as u64).clamp(1, span);
        lo + self.rng.below(eff) as usize
    }

    /// Arbitrary u32 (full range; not scaled — for value-semantics draws).
    pub fn u32_any(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn u64_any(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        let i = self.rng.below(xs.len() as u64) as usize;
        &xs[i]
    }

    /// Vec of length in [0, max_len] with elements from f.
    pub fn vec_of<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Outcome of a property check run.
#[derive(Debug)]
pub struct Failure {
    pub name: String,
    pub seed: u64,
    pub scale: f64,
    pub case: usize,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property '{}' failed: case #{} seed={} scale={:.3} \
             (replay with Gen::replay({}, {:.3}))",
            self.name, self.case, self.seed, self.scale, self.seed, self.scale
        )
    }
}

impl Gen {
    /// Rebuild the exact generator of a reported failure.
    pub fn replay(seed: u64, scale: f64) -> Self {
        Gen::new(seed, scale)
    }
}

/// Run `cases` random cases of `prop`. On failure, greedily shrink by
/// re-running the same seed at smaller scales and report the smallest
/// failing configuration. Panics with a replayable message on failure.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> bool) {
    // Fixed base seed for reproducible CI; vary per-case.
    let base = 0xFA57_5EEDu64;
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed, 1.0);
        if prop(&mut g) {
            continue;
        }
        // Shrink: find the smallest scale that still fails.
        let mut failing_scale = 1.0;
        for &scale in &[0.5, 0.25, 0.1, 0.05, 0.01] {
            let mut g = Gen::new(seed, scale);
            if !prop(&mut g) {
                failing_scale = scale;
            }
        }
        panic!(
            "{}",
            Failure { name: name.to_string(), seed, scale: failing_scale, case }
        );
    }
}

/// Like `check` but the property returns Result with a diagnostic.
pub fn check_diag(
    name: &str,
    cases: usize,
    mut prop: impl FnMut(&mut Gen) -> Result<(), String>,
) {
    check(name, cases, |g| match prop(g) {
        Ok(()) => true,
        Err(msg) => {
            eprintln!("[quickprop:{name}] {msg}");
            false
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u32_below bound", 500, |g| {
            let n = 1 + g.u32_below(1000);
            g.u32_below(n) < n
        });
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_replay_info() {
        check("always-false", 10, |_| false);
    }

    #[test]
    fn usize_in_respects_bounds() {
        check("usize_in bounds", 500, |g| {
            let x = g.usize_in(3, 17);
            (3..=17).contains(&x)
        });
    }

    #[test]
    fn vec_of_bounded() {
        check("vec_of len", 200, |g| {
            let v = g.vec_of(32, |g| g.u32_any());
            v.len() <= 32
        });
    }

    #[test]
    fn replay_reproduces_draws() {
        let mut a = Gen::replay(99, 1.0);
        let mut b = Gen::replay(99, 1.0);
        for _ in 0..50 {
            assert_eq!(a.u64_any(), b.u64_any());
        }
    }

    #[test]
    fn choose_picks_members() {
        let xs = [1, 2, 3];
        check("choose member", 100, |g| xs.contains(g.choose(&xs)));
    }
}
