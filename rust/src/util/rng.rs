//! Deterministic PRNG for Monte Carlo simulation and property tests.
//!
//! `rand` is not in the offline vendor set; the simulator needs a fast,
//! seedable, statistically-decent generator — xoshiro256++ (Blackman &
//! Vigna) plus a splitmix64 seeder, both public-domain algorithms,
//! implemented here and unit-tested against published reference vectors.

/// splitmix64: used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 (expanded via splitmix64, per the authors'
    /// recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) double, the canonical conversion.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (polar form avoided for simplicity;
    /// callers are MC loops where two trig calls are fine).
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300; // avoid ln(0)
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vectors() {
        // Reference values for seed 1234567 (widely reproduced test vector).
        let mut s = 1234567u64;
        let v: Vec<u64> = (0..3).map(|_| splitmix64(&mut s)).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
