//! Small statistics helpers for benches and metrics: trimmed means,
//! percentiles, and a streaming histogram. Criterion is not in the
//! offline vendor set, so the bench harness builds on these.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation; 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Mean after dropping the lowest and highest `trim_frac` of samples —
/// robust to scheduler noise in wall-clock benches.
pub fn trimmed_mean(xs: &[f64], trim_frac: f64) -> f64 {
    assert!((0.0..0.5).contains(&trim_frac));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = (v.len() as f64 * trim_frac).floor() as usize;
    let kept = &v[k..v.len() - k];
    mean(kept)
}

/// Linear-interpolated percentile, p ∈ [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if v.len() == 1 {
        return v[0];
    }
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Min of a slice (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Max of a slice (NaN-free input assumed).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Fixed-bucket latency histogram (power-of-two nanosecond buckets),
/// allocation-free on the record path — used by coordinator metrics.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// buckets[i] counts samples in [2^i, 2^(i+1)) ns; bucket 0 is [0,2).
    buckets: [u64; 48],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: [0; 48], count: 0, sum_ns: 0, max_ns: 0 }
    }

    #[inline]
    pub fn record(&mut self, ns: u64) {
        let idx = (64 - ns.max(1).leading_zeros() as usize - 1).min(47);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Upper bound of the bucket containing the p-th percentile sample.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        assert!((0.0..=100.0).contains(&p));
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_ns
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn trimmed_mean_drops_outliers() {
        let mut xs: Vec<f64> = (0..100).map(|i| 10.0 + i as f64 * 0.01).collect();
        xs.push(10_000.0); // wild outlier
        let t = trimmed_mean(&xs, 0.05);
        assert!(t < 11.0, "trimmed mean {t}");
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_bracket() {
        let mut h = LatencyHistogram::new();
        for ns in [100u64, 200, 300, 400, 50_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean_ns() > 0.0);
        // p50 bucket upper bound must be >= 200 and well below the outlier.
        let p50 = h.percentile_ns(50.0);
        assert!((256..=512).contains(&p50), "p50 {p50}");
        assert!(h.percentile_ns(100.0) >= 50_000 / 2);
        assert_eq!(h.max_ns(), 50_000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(100);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_ns(), 1000);
    }
}
