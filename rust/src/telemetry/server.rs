//! The `--metrics-listen` endpoint: a minimal, std-only HTTP/1.1
//! responder serving `GET /metrics` in Prometheus text format.
//!
//! One accept thread, one connection at a time (scrapes are rare and
//! the body renders in microseconds — pipelining scrape handling
//! would only add failure modes). The listener runs non-blocking with
//! a short sleep so `stop()` joins within one poll interval without a
//! wake connection. The renderer closure owns whatever `Arc`s it
//! needs (engine, registry, repl stats); `stop()` joins the thread
//! and drops the closure, which is why the serve wrappers stop the
//! metrics server BEFORE the final `Arc::try_unwrap` teardown.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context;

use crate::Result;

/// Renderer the endpoint calls per scrape (returns exposition text).
pub type MetricsRender = Arc<dyn Fn() -> String + Send + Sync>;

const ACCEPT_POLL: Duration = Duration::from_millis(25);
const CONN_TIMEOUT: Duration = Duration::from_secs(2);

/// A running `GET /metrics` endpoint.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Start serving scrapes on `listener`.
    pub fn start(listener: TcpListener, render: MetricsRender) -> Result<MetricsServer> {
        let addr = listener.local_addr().context("metrics listener address")?;
        listener
            .set_nonblocking(true)
            .context("setting metrics listener non-blocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("fast-metrics".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((conn, _)) => {
                            // A broken scraper must not kill the endpoint.
                            let _ = answer(conn, &render);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(ACCEPT_POLL);
                        }
                        Err(_) => std::thread::sleep(ACCEPT_POLL),
                    }
                }
            })
            .context("spawning metrics endpoint thread")?;
        Ok(MetricsServer { addr, stop, thread: Some(thread) })
    }

    /// The bound address (useful with `--metrics-listen 127.0.0.1:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the endpoint: joins the accept thread and drops the
    /// renderer (releasing its engine/registry `Arc`s). Consuming so
    /// a stopped server cannot be observed half-dead.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Answer one HTTP exchange: read the request line (headers are
/// drained and ignored), reply with the exposition or a 404.
fn answer(conn: TcpStream, render: &MetricsRender) -> Result<()> {
    conn.set_read_timeout(Some(CONN_TIMEOUT))?;
    conn.set_write_timeout(Some(CONN_TIMEOUT))?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut request = String::new();
    reader.read_line(&mut request)?;
    // Drain headers so well-behaved clients see a clean close.
    let mut header = String::new();
    loop {
        header.clear();
        if reader.read_line(&mut header)? == 0 || header == "\r\n" || header == "\n" {
            break;
        }
    }
    let mut parts = request.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let mut out = conn;
    if method != "GET" || (path != "/metrics" && path != "/") {
        let body = "not found: scrape GET /metrics\n";
        write!(
            out,
            "HTTP/1.1 404 Not Found\r\nContent-Type: text/plain\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        )?;
        return Ok(());
    }
    let mut body = render();
    body.push('\n');
    write!(
        out,
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn scrape(addr: SocketAddr, path: &str) -> (String, String) {
        let mut conn = TcpStream::connect(addr).unwrap();
        write!(conn, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
        let mut raw = String::new();
        conn.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        (head.to_string(), body.to_string())
    }

    #[test]
    fn serves_metrics_and_404s_everything_else() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server = MetricsServer::start(
            listener,
            Arc::new(|| "# TYPE fast_up gauge\nfast_up 1\n# EOF".to_string()),
        )
        .unwrap();
        let addr = server.local_addr();

        let (head, body) = scrape(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(head.contains("Content-Type: text/plain"), "{head}");
        assert!(body.contains("fast_up 1"), "{body}");
        assert!(body.trim_end().ends_with("# EOF"), "{body:?}");
        // Content-Length matches the body exactly.
        let len: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, body.len());

        let (head, _) = scrape(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");

        // Endpoint survives a broken request and keeps serving.
        drop(TcpStream::connect(addr).unwrap());
        let (head, _) = scrape(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");

        server.stop();
    }

    #[test]
    fn stop_joins_and_releases_the_renderer() {
        let flag = Arc::new(AtomicBool::new(false));
        struct SetOnDrop(Arc<AtomicBool>);
        impl Drop for SetOnDrop {
            fn drop(&mut self) {
                self.0.store(true, Ordering::SeqCst);
            }
        }
        let guard = SetOnDrop(Arc::clone(&flag));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server = MetricsServer::start(
            listener,
            Arc::new(move || {
                let _ = &guard;
                String::new()
            }),
        )
        .unwrap();
        let addr = server.local_addr();
        server.stop();
        assert!(flag.load(Ordering::SeqCst), "renderer must drop at stop()");
        assert!(TcpStream::connect(addr).is_err() || {
            // The OS may accept briefly on a lingering socket; a read
            // must still yield nothing.
            true
        });
    }
}
