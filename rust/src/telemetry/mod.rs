//! Always-on, hot-path-safe observability: sampled request-span
//! tracing, per-stage latency histograms, a bounded in-memory
//! time-series ring, and the Prometheus exposition surface behind
//! `fast serve --metrics-listen` / the `METRICS` wire verb.
//!
//! ## Span lifecycle
//!
//! A *span* follows one sampled update request through the full
//! pipeline:
//!
//! ```text
//! submit ──► enqueue ──► seal ──► backend-apply ──► WAL-append ──► (fsync) ──► ticket-resolve
//! t_submit   t_enqueue   t_seal   t_apply           t_wal          t_fsync     t_resolve
//! ```
//!
//! Every timestamp is monotonic nanoseconds since a process-wide
//! epoch ([`now_ns`]; 0 is reserved for "absent"). The submitter
//! stamps `t_submit`; everything else is stamped by the shard worker,
//! which owns the request from dequeue to ticket resolution. `t_fsync`
//! is the shard's *last observed* fsync completion (stored by the WAL
//! appender into `ShardCounters::last_fsync_ns`) — under coalesced
//! fsync policies the sync happens after resolution, so the stage is
//! reported as a lag gauge, not a strict sub-interval.
//!
//! ## Hot-path contract
//!
//! Sampling adds **zero allocations and zero locks** to submit and
//! commit paths, enforced by `tests/alloc_steady_state.rs`:
//!
//! - The sampling decision is one relaxed `fetch_add` on a per-shard
//!   admission sequence plus a pure splitmix64 hash of
//!   `(seed, shard, seq)` — seed-deterministic, so the *set* of
//!   sampled requests is a pure function of the seed and admission
//!   order (property-tested below).
//! - A sampled stamp travels inside the already-allocated queue
//!   command as a plain `u64` (0 = unsampled).
//! - Completed spans are published over a per-shard bounded SPSC ring
//!   ([`SpanRing`]; single producer = the shard worker). When the ring
//!   is full the span is *dropped and counted* — telemetry never
//!   applies backpressure to commits.
//!
//! A background drain thread (one per engine, started with the engine
//! and joined at shutdown) empties the rings into per-stage
//! [`LatencyHistogram`]s and appends rate-window points (completed
//! ops, WAL bytes, queue depth, replication lag) to a bounded
//! time-series ring; scrape-time rates are computed from the window
//! ends, so the hot path never touches a clock it didn't already own.

pub mod expo;
pub mod server;

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::metrics::LatencySummary;
use crate::util::stats::LatencyHistogram;

/// Process-wide monotonic clock epoch: every span timestamp is
/// nanoseconds since the first call. 0 is reserved as "no timestamp",
/// so the first tick reports 1.
static EPOCH: OnceLock<std::time::Instant> = OnceLock::new();

/// Monotonic nanoseconds since the process telemetry epoch, never 0.
#[inline]
pub fn now_ns() -> u64 {
    let epoch = *EPOCH.get_or_init(std::time::Instant::now);
    (epoch.elapsed().as_nanos() as u64).max(1)
}

/// splitmix64 finalizer — the sampling hash. Pure, allocation-free,
/// and statistically uniform enough that a power-of-two mask selects
/// an unbiased 1/rate subset.
#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Span-tracing knobs, embedded in `EngineConfig`. Always-on by
/// default at a 1/64 sampling rate — the overhead budget is proven by
/// `fast bench engine`'s tracing-on/off leg (`BENCH_telemetry_overhead.json`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Master switch. Off = `submit_stamp` returns 0 unconditionally.
    pub enabled: bool,
    /// Sample 1 in `sample_rate` admissions. Must be a power of two
    /// (the decision is a mask, not a division). 1 = sample everything.
    pub sample_rate: u64,
    /// Sampling seed: the sampled request *set* is a pure function of
    /// `(seed, shard, admission_seq)`.
    pub seed: u64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { enabled: true, sample_rate: 64, seed: 0xFA57_77AC }
    }
}

/// One completed request span: monotonic stage timestamps, 0 = stage
/// absent. Plain `Copy` data — ring slots never allocate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpanEvent {
    pub t_submit: u64,
    pub t_enqueue: u64,
    pub t_seal: u64,
    pub t_apply: u64,
    pub t_wal: u64,
    /// Last fsync completion observed by the shard at resolve time
    /// (0 when durability is off or nothing has synced yet).
    pub t_fsync: u64,
    pub t_resolve: u64,
}

/// Span ring capacity per shard. Power of two; at the default 1/64
/// sampling a shard must fall ~64k requests behind the drain thread
/// before spans drop (and drops are counted, never blocking).
const SPAN_RING_CAP: usize = 1024;

/// Bounded single-producer/single-consumer ring of [`SpanEvent`]s.
/// The producer is the shard worker (exclusive by construction), the
/// consumer is the telemetry drain thread. Full ring = drop, and the
/// caller counts it; `push` is wait-free and allocation-free.
pub struct SpanRing {
    slots: Box<[UnsafeCell<SpanEvent>]>,
    /// Consumer cursor (monotonic; slot = head & (cap-1)).
    head: AtomicUsize,
    /// Producer cursor.
    tail: AtomicUsize,
}

// SAFETY: slot i is written only by the producer while
// `tail - head < cap` guarantees the consumer is not reading it, and
// read only by the consumer after the producer's Release store of
// `tail` makes the write visible. One producer, one consumer.
unsafe impl Sync for SpanRing {}
unsafe impl Send for SpanRing {}

impl SpanRing {
    fn with_capacity(cap: usize) -> SpanRing {
        assert!(cap.is_power_of_two(), "span ring capacity must be a power of two");
        let slots: Vec<UnsafeCell<SpanEvent>> =
            (0..cap).map(|_| UnsafeCell::new(SpanEvent::default())).collect();
        SpanRing {
            slots: slots.into_boxed_slice(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Producer side: publish one span. Returns false (span dropped)
    /// when the ring is full. Never blocks, never allocates.
    #[inline]
    pub fn push(&self, ev: SpanEvent) -> bool {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == self.slots.len() {
            return false;
        }
        let idx = tail & (self.slots.len() - 1);
        // SAFETY: see the Sync impl — this slot is exclusively ours
        // until the tail store below publishes it.
        unsafe { *self.slots[idx].get() = ev };
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side: take the oldest span, if any.
    #[inline]
    pub fn pop(&self) -> Option<SpanEvent> {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        let idx = head & (self.slots.len() - 1);
        // SAFETY: the producer's Release store of `tail` happens-before
        // our Acquire load, and it will not reuse this slot until our
        // Release store of `head` below.
        let ev = unsafe { *self.slots[idx].get() };
        self.head.store(head.wrapping_add(1), Ordering::Release);
        Some(ev)
    }

    /// Spans currently buffered (racy snapshot; exact in tests where
    /// both sides are quiescent).
    pub fn len(&self) -> usize {
        self.tail
            .load(Ordering::Acquire)
            .wrapping_sub(self.head.load(Ordering::Acquire))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-shard span-tracing state: the admission sequence the sampling
/// decision hashes, the SPSC ring, and the sampled/dropped counters.
pub struct ShardSpanState {
    seed: u64,
    /// `sample_rate - 1`; sampling is `hash & mask == 0`.
    mask: u64,
    enabled: bool,
    /// Pre-mixed shard identity so distinct shards sample distinct
    /// admission indices under the same seed.
    shard_salt: u64,
    /// Admission sequence: one relaxed `fetch_add` per submit.
    seq: AtomicU64,
    pub ring: SpanRing,
    /// Spans whose stamp was minted (sampled admissions).
    pub sampled: AtomicU64,
    /// Completed spans dropped because the ring was full.
    pub dropped: AtomicU64,
}

impl ShardSpanState {
    fn new(cfg: &TelemetryConfig, shard: usize) -> ShardSpanState {
        ShardSpanState {
            seed: cfg.seed,
            mask: cfg.sample_rate - 1,
            enabled: cfg.enabled,
            shard_salt: splitmix64(shard as u64 ^ 0x5A17_D05E),
            seq: AtomicU64::new(0),
            ring: SpanRing::with_capacity(SPAN_RING_CAP),
            sampled: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// The pure sampling decision for admission `seq` — exposed so
    /// tests can enumerate the expected sampled set.
    #[inline]
    pub fn decides(&self, seq: u64) -> bool {
        self.enabled && splitmix64(self.seed ^ self.shard_salt ^ seq) & self.mask == 0
    }

    /// Called by the submitter, once per admitted request (or chunk):
    /// mints a `t_submit` stamp when this admission is sampled, else
    /// returns 0. One relaxed `fetch_add` + one hash; no locks, no
    /// allocations, no clock read on the unsampled path.
    #[inline]
    pub fn submit_stamp(&self) -> u64 {
        if !self.enabled {
            return 0;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        if !self.decides(seq) {
            return 0;
        }
        self.sampled.fetch_add(1, Ordering::Relaxed);
        now_ns()
    }

    /// Worker side: publish a completed span (drop-and-count on full).
    #[inline]
    pub fn record(&self, ev: SpanEvent) {
        if !self.ring.push(ev) {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Worker-local carry between admission and seal: the sampled
/// request's submit stamp plus its dequeue time. At most one per open
/// batch (first sampled request wins); resolved by the seal that
/// commits it.
#[derive(Debug, Clone, Copy)]
pub struct PendingSpan {
    pub t_submit: u64,
    pub t_enqueue: u64,
}

/// Span stage names, in pipeline order. `fsync_lag` is resolve→fsync
/// distance (coalesced fsync runs behind resolution by design).
pub const STAGE_NAMES: [&str; 7] =
    ["enqueue", "batch", "apply", "wal", "resolve", "total", "fsync_lag"];

const STAGES: usize = STAGE_NAMES.len();

/// One rate-window sample appended by the drain thread.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeriesPoint {
    /// Monotonic stamp ([`now_ns`]).
    pub t_ns: u64,
    /// Cumulative completed requests at the stamp.
    pub completed: u64,
    /// Cumulative WAL bytes at the stamp.
    pub wal_bytes: u64,
    /// Instantaneous total queue depth.
    pub queue_depth: u64,
    /// Instantaneous total replication lag (LSNs), 0 when no repl.
    pub repl_lag_lsn: u64,
}

/// Instantaneous engine gauges the drain thread snapshots into
/// [`SeriesPoint`]s — supplied by the engine as a closure so this
/// module stays dependency-free of the coordinator.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeriesSample {
    pub completed: u64,
    pub wal_bytes: u64,
    pub queue_depth: u64,
}

/// Time-series ring capacity (~64 s of history at the 250 ms cadence).
const SERIES_CAP: usize = 256;

/// Drain-thread cadence: ring drains each tick, series points every
/// `SERIES_EVERY` ticks.
const DRAIN_TICK: Duration = Duration::from_millis(5);
const SERIES_EVERY: u32 = 50;

/// Scrape-time aggregate of the telemetry layer.
#[derive(Debug, Clone, Default)]
pub struct TelemetrySnapshot {
    pub enabled: bool,
    pub sample_rate: u64,
    /// Sampled admissions across all shards.
    pub spans_sampled: u64,
    /// Completed spans dropped on full rings.
    pub spans_dropped: u64,
    /// Per-stage latency summaries, in [`STAGE_NAMES`] order.
    pub stages: Vec<(&'static str, LatencySummary)>,
    /// Completed-requests rate over the series window.
    pub ops_per_sec: f64,
    /// WAL append rate over the series window.
    pub wal_bytes_per_sec: f64,
    /// Latest queue-depth gauge from the series (0 when empty).
    pub queue_depth: u64,
    /// Latest replication-lag gauge from the series.
    pub repl_lag_lsn: u64,
    /// Series points currently buffered.
    pub series_len: usize,
}

type LagSource = dyn Fn() -> u64 + Send + Sync;

/// Engine-level telemetry hub: per-shard span states, the stage
/// histograms and time-series the drain thread feeds, and the drain
/// thread itself. Owned by `UpdateEngine` via `Arc`.
pub struct Telemetry {
    cfg: TelemetryConfig,
    shards: Vec<Arc<ShardSpanState>>,
    stages: Mutex<[LatencyHistogram; STAGES]>,
    series: Mutex<VecDeque<SeriesPoint>>,
    /// Replication-lag gauge source (installed by serve wiring when a
    /// repl role exists; absent = series report 0 lag).
    lag_source: Mutex<Option<Box<LagSource>>>,
    stop: AtomicBool,
    drain: Mutex<Option<JoinHandle<()>>>,
}

impl Telemetry {
    pub fn new(cfg: TelemetryConfig, shards: usize) -> Telemetry {
        assert!(cfg.sample_rate.is_power_of_two(), "sample_rate must be a power of two");
        Telemetry {
            cfg,
            shards: (0..shards).map(|s| Arc::new(ShardSpanState::new(&cfg, s))).collect(),
            stages: Mutex::new(std::array::from_fn(|_| LatencyHistogram::new())),
            series: Mutex::new(VecDeque::with_capacity(SERIES_CAP)),
            lag_source: Mutex::new(None),
            stop: AtomicBool::new(false),
            drain: Mutex::new(None),
        }
    }

    pub fn config(&self) -> &TelemetryConfig {
        &self.cfg
    }

    /// The per-shard span state handed to that shard's worker.
    pub fn shard(&self, shard: usize) -> Arc<ShardSpanState> {
        Arc::clone(&self.shards[shard])
    }

    /// Submit-path stamp for `shard` (see [`ShardSpanState::submit_stamp`]).
    #[inline]
    pub fn submit_stamp(&self, shard: usize) -> u64 {
        self.shards[shard].submit_stamp()
    }

    /// Install the replication-lag gauge source (sum of per-shard
    /// `lag_lsn`). Called by serve wiring; absent = 0 in the series.
    pub fn set_lag_source(&self, f: impl Fn() -> u64 + Send + Sync + 'static) {
        *self.lag_source.lock().expect("telemetry lag source poisoned") = Some(Box::new(f));
    }

    /// Drain every shard ring into the stage histograms. Called by the
    /// drain thread each tick and by `snapshot` for freshness.
    pub fn drain_rings(&self) {
        let mut stages = self.stages.lock().expect("telemetry stages poisoned");
        for shard in &self.shards {
            while let Some(ev) = shard.ring.pop() {
                record_span(&mut stages, &ev);
            }
        }
    }

    fn push_series_point(&self, sample: SeriesSample) {
        let lag = {
            let src = self.lag_source.lock().expect("telemetry lag source poisoned");
            src.as_ref().map(|f| f()).unwrap_or(0)
        };
        let point = SeriesPoint {
            t_ns: now_ns(),
            completed: sample.completed,
            wal_bytes: sample.wal_bytes,
            queue_depth: sample.queue_depth,
            repl_lag_lsn: lag,
        };
        let mut series = self.series.lock().expect("telemetry series poisoned");
        if series.len() == SERIES_CAP {
            series.pop_front();
        }
        series.push_back(point);
    }

    /// Spawn the drain thread. `sample` reads the engine's cumulative
    /// gauges for series points. Idempotent per engine start (the
    /// engine calls it exactly once, after every worker is live).
    pub fn start_drain(
        self: &Arc<Self>,
        sample: impl Fn() -> SeriesSample + Send + 'static,
    ) {
        let tel = Arc::clone(self);
        let handle = std::thread::Builder::new()
            .name("fast-telemetry".into())
            .spawn(move || {
                let mut tick = 0u32;
                loop {
                    if tel.stop.load(Ordering::Acquire) {
                        break;
                    }
                    tel.drain_rings();
                    if tick % SERIES_EVERY == 0 {
                        tel.push_series_point(sample());
                    }
                    tick = tick.wrapping_add(1);
                    std::thread::sleep(DRAIN_TICK);
                }
                // Final sweep so shutdown loses no buffered spans.
                tel.drain_rings();
                tel.push_series_point(sample());
            })
            .expect("spawning telemetry drain thread");
        *self.drain.lock().expect("telemetry drain poisoned") = Some(handle);
    }

    /// Stop and join the drain thread. Idempotent — engine shutdown
    /// and Drop both call it.
    pub fn stop_drain(&self) {
        self.stop.store(true, Ordering::Release);
        let handle = self.drain.lock().expect("telemetry drain poisoned").take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Aggregate view for stats surfaces and the exposition endpoint.
    /// Drains rings first so a scrape never lags the hot path by more
    /// than the ring contents.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        self.drain_rings();
        let stages = {
            let hists = self.stages.lock().expect("telemetry stages poisoned");
            STAGE_NAMES
                .iter()
                .zip(hists.iter())
                .map(|(name, h)| (*name, summarize(h)))
                .collect()
        };
        let series = self.series.lock().expect("telemetry series poisoned");
        let (mut ops_per_sec, mut wal_bytes_per_sec) = (0.0, 0.0);
        if let (Some(first), Some(last)) = (series.front(), series.back()) {
            let dt = last.t_ns.saturating_sub(first.t_ns) as f64 / 1e9;
            if dt > 0.0 {
                ops_per_sec = last.completed.saturating_sub(first.completed) as f64 / dt;
                wal_bytes_per_sec = last.wal_bytes.saturating_sub(first.wal_bytes) as f64 / dt;
            }
        }
        TelemetrySnapshot {
            enabled: self.cfg.enabled,
            sample_rate: self.cfg.sample_rate,
            spans_sampled: self.shards.iter().map(|s| s.sampled.load(Ordering::Relaxed)).sum(),
            spans_dropped: self.shards.iter().map(|s| s.dropped.load(Ordering::Relaxed)).sum(),
            stages,
            ops_per_sec,
            wal_bytes_per_sec,
            queue_depth: series.back().map(|p| p.queue_depth).unwrap_or(0),
            repl_lag_lsn: series.back().map(|p| p.repl_lag_lsn).unwrap_or(0),
            series_len: series.len(),
        }
    }

    /// The raw series window (oldest first) — consumed by `fast stats`
    /// style renderings and tests.
    pub fn series(&self) -> Vec<SeriesPoint> {
        self.series.lock().expect("telemetry series poisoned").iter().copied().collect()
    }
}

impl Drop for Telemetry {
    fn drop(&mut self) {
        self.stop_drain();
    }
}

/// Fold one span into the stage histograms. Stages with an absent
/// endpoint (0) are skipped; monotone clamping (`saturating_sub`)
/// guards the cross-thread submit stamp.
fn record_span(stages: &mut [LatencyHistogram; STAGES], ev: &SpanEvent) {
    let deltas = [
        (0, ev.t_submit, ev.t_enqueue),
        (1, ev.t_enqueue, ev.t_seal),
        (2, ev.t_seal, ev.t_apply),
        (3, ev.t_apply, ev.t_wal),
        (4, ev.t_wal, ev.t_resolve),
        (5, ev.t_submit, ev.t_resolve),
        (6, ev.t_fsync, ev.t_resolve),
    ];
    for (idx, from, to) in deltas {
        if from != 0 && to != 0 {
            stages[idx].record(to.saturating_sub(from));
        }
    }
}

fn summarize(h: &LatencyHistogram) -> LatencySummary {
    LatencySummary {
        count: h.count(),
        mean_ns: h.mean_ns(),
        p50_ns: h.percentile_ns(50.0),
        p95_ns: h.percentile_ns(95.0),
        p99_ns: h.percentile_ns(99.0),
        max_ns: h.max_ns(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::check;
    use crate::util::rng::Rng;

    fn state(seed: u64, rate: u64, shard: usize) -> ShardSpanState {
        ShardSpanState::new(
            &TelemetryConfig { enabled: true, sample_rate: rate, seed },
            shard,
        )
    }

    #[test]
    fn now_ns_is_monotone_and_never_zero() {
        let a = now_ns();
        let b = now_ns();
        assert!(a >= 1);
        assert!(b >= a);
    }

    #[test]
    fn span_ring_is_fifo_and_drops_on_full() {
        let ring = SpanRing::with_capacity(4);
        for i in 1..=4u64 {
            assert!(ring.push(SpanEvent { t_submit: i, ..SpanEvent::default() }));
        }
        assert!(!ring.push(SpanEvent { t_submit: 99, ..SpanEvent::default() }), "full ring drops");
        for i in 1..=4u64 {
            assert_eq!(ring.pop().unwrap().t_submit, i);
        }
        assert!(ring.pop().is_none());
        // Wrap-around keeps FIFO order.
        for i in 10..=12u64 {
            assert!(ring.push(SpanEvent { t_submit: i, ..SpanEvent::default() }));
        }
        assert_eq!(ring.pop().unwrap().t_submit, 10);
        assert_eq!(ring.len(), 2);
    }

    #[test]
    fn rate_one_samples_every_admission() {
        let s = state(7, 1, 0);
        for _ in 0..100 {
            assert_ne!(s.submit_stamp(), 0);
        }
        assert_eq!(s.sampled.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn disabled_sampling_stamps_nothing() {
        let s = ShardSpanState::new(
            &TelemetryConfig { enabled: false, ..TelemetryConfig::default() },
            0,
        );
        for _ in 0..100 {
            assert_eq!(s.submit_stamp(), 0);
        }
        assert_eq!(s.sampled.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        // Same (seed, rate, shard) → identical sampled admission set;
        // different seeds → (overwhelmingly) different sets.
        check("span_sampling_seed_deterministic", 64, |g: &mut Rng| {
            let seed = g.below(1 << 40) as u64;
            let rate = 1u64 << g.below(7); // 1..=64
            let shard = g.below(8) as usize;
            let n = 256 + g.below(256) as u64;
            let a = state(seed, rate, shard);
            let b = state(seed, rate, shard);
            let set_a: Vec<bool> = (0..n).map(|_| a.submit_stamp() != 0).collect();
            let set_b: Vec<bool> = (0..n).map(|_| b.submit_stamp() != 0).collect();
            // The decision predicate agrees with the stamps minted.
            let decided: Vec<bool> = (0..n).map(|i| b.decides(i)).collect();
            set_a == set_b && set_a == decided
        });
    }

    #[test]
    fn shards_sample_independent_sets_under_one_seed() {
        let a = state(42, 8, 0);
        let b = state(42, 8, 1);
        let set_a: Vec<bool> = (0..512).map(|i| a.decides(i)).collect();
        let set_b: Vec<bool> = (0..512).map(|i| b.decides(i)).collect();
        assert_ne!(set_a, set_b, "shard salt must decorrelate shards");
    }

    #[test]
    fn sampling_rate_is_roughly_honoured() {
        let s = state(1234, 16, 0);
        let n = 16_384u64;
        let sampled = (0..n).filter(|&i| s.decides(i)).count() as u64;
        let expect = n / 16;
        assert!(
            sampled > expect / 2 && sampled < expect * 2,
            "sampled {sampled} of {n} at rate 16"
        );
    }

    #[test]
    fn record_span_fills_stages_and_skips_absent_fsync() {
        let tel = Telemetry::new(TelemetryConfig { sample_rate: 1, ..Default::default() }, 1);
        tel.shards[0].record(SpanEvent {
            t_submit: 100,
            t_enqueue: 150,
            t_seal: 400,
            t_apply: 600,
            t_wal: 700,
            t_fsync: 0,
            t_resolve: 800,
        });
        let snap = tel.snapshot();
        let get = |name: &str| {
            snap.stages
                .iter()
                .find(|(n, _)| *n == name)
                .map(|(_, s)| *s)
                .unwrap()
        };
        assert_eq!(get("enqueue").count, 1);
        assert_eq!(get("batch").count, 1);
        assert_eq!(get("apply").count, 1);
        assert_eq!(get("wal").count, 1);
        assert_eq!(get("resolve").count, 1);
        assert_eq!(get("total").count, 1);
        assert_eq!(get("fsync_lag").count, 0, "fsync stage absent when t_fsync=0");
        assert!(get("total").mean_ns >= 699.0);
    }

    #[test]
    fn drain_thread_builds_series_and_rates() {
        let tel = Arc::new(Telemetry::new(TelemetryConfig::default(), 1));
        let ticks = Arc::new(AtomicU64::new(0));
        let t2 = Arc::clone(&ticks);
        tel.start_drain(move || {
            // A fake engine completing 1000 ops per sample.
            let n = t2.fetch_add(1, Ordering::Relaxed) + 1;
            SeriesSample { completed: n * 1000, wal_bytes: n * 4096, queue_depth: 3 }
        });
        // Wait for at least two series points (0 and SERIES_EVERY ticks).
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while tel.series().len() < 2 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        tel.stop_drain();
        let snap = tel.snapshot();
        assert!(snap.series_len >= 2, "series never grew: {}", snap.series_len);
        assert!(snap.ops_per_sec > 0.0);
        assert!(snap.wal_bytes_per_sec > 0.0);
        assert_eq!(snap.queue_depth, 3);
        // Idempotent stop.
        tel.stop_drain();
    }

    #[test]
    fn series_ring_is_bounded() {
        let tel = Telemetry::new(TelemetryConfig::default(), 1);
        for i in 0..(SERIES_CAP as u64 + 100) {
            tel.push_series_point(SeriesSample {
                completed: i,
                wal_bytes: 0,
                queue_depth: 0,
            });
        }
        let series = tel.series();
        assert_eq!(series.len(), SERIES_CAP);
        // Oldest points were evicted.
        assert_eq!(series.last().unwrap().completed, SERIES_CAP as u64 + 99);
        assert!(series.first().unwrap().completed >= 100);
    }
}
