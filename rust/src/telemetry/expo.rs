//! Prometheus text exposition (hand-rolled, std-only) plus the
//! minimal parser the tests and the `fast stats` client share.
//!
//! ## Grammar emitted
//!
//! ```text
//! # HELP <family> <help text>
//! # TYPE <family> counter|gauge|summary
//! <family>[{label="value",...}] <number>
//! ...
//! # EOF
//! ```
//!
//! Counters end in `_total`. Histogram families are emitted as
//! summaries: one sample per quantile (`{quantile="0.5|0.95|0.99"}`)
//! plus `<family>_count` and `<family>_sum`. Per-shard series carry a
//! `shard` label; in `--tenants` mode every series additionally
//! carries a `tenant` label and the `fast_tenant_*` families appear.
//! Replication families are ALWAYS emitted (zeros when the server has
//! no replication role) so a scrape's family set never depends on the
//! deployment shape. The final `# EOF` line doubles as the `METRICS`
//! wire verb's terminator.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

use crate::coordinator::EngineStats;
use crate::metrics::LatencySummary;
use crate::replication::ReplSnapshot;
use crate::Result;

use super::TelemetrySnapshot;

/// Every family the single-engine exposition documents — the
/// load-bearing list: ARCHITECTURE.md tabulates it, the round-trip
/// test asserts each is present and well-formed, and the CI
/// telemetry-smoke job greps them out of a live scrape.
pub const DOCUMENTED_FAMILIES: &[&str] = &[
    // engine
    "fast_backend_info",
    "fast_requests_submitted_total",
    "fast_requests_completed_total",
    "fast_requests_rejected_total",
    "fast_batches_sealed_total",
    "fast_rows_updated_total",
    "fast_coalesce_hits_total",
    "fast_tickets_resolved_total",
    "fast_queries_total",
    "fast_modeled_ns_total",
    "fast_modeled_energy_pj_total",
    "fast_queue_depth",
    "fast_queue_high_water",
    "fast_commit_seq",
    "fast_apply_wall_ns",
    "fast_commit_wall_ns",
    "fast_commit_modeled_ns",
    "fast_query_wall_ns",
    // seal reasons
    "fast_seal_total",
    // contention
    "fast_submit_spins_total",
    "fast_park_events_total",
    "fast_wake_batch",
    // WAL
    "fast_wal_records_total",
    "fast_wal_bytes_total",
    "fast_wal_fsyncs_total",
    "fast_wal_rotations_total",
    "fast_wal_fsync_ns",
    "fast_wal_coalesced_writes_total",
    "fast_wal_coalesced_frames_total",
    // replication (zero-valued without a repl role)
    "fast_repl_epoch",
    "fast_repl_connected",
    "fast_repl_failed",
    "fast_repl_reconnects_total",
    "fast_repl_frames_applied_total",
    "fast_repl_dup_frames_total",
    "fast_repl_wire_errors_total",
    "fast_repl_digests_verified_total",
    "fast_repl_lag_lsn",
    // span tracing
    "fast_spans_sampled_total",
    "fast_spans_dropped_total",
    "fast_span_stage_ns",
    "fast_ops_per_sec",
    "fast_wal_bytes_per_sec",
];

/// Families additionally present in `--tenants` mode.
pub const TENANT_FAMILIES: &[&str] =
    &["fast_tenants", "fast_tenant_rows", "fast_tenant_quota_rows", "fast_tenant_q"];

/// Identity of one tenant scope (`None` labels on a single-engine
/// serve; name/rows/q/quota for a tenant).
#[derive(Debug, Clone)]
pub struct TenantMeta {
    pub name: String,
    pub rows: usize,
    pub q: usize,
    pub quota_rows: usize,
}

/// One engine's worth of scrape input: its stats, its telemetry
/// snapshot, and (in tenants mode) the tenant it belongs to.
pub struct Scope<'a> {
    pub tenant: Option<TenantMeta>,
    pub stats: &'a EngineStats,
    pub tel: Option<&'a TelemetrySnapshot>,
}

const QUANTILES: [(&str, fn(&LatencySummary) -> u64); 3] = [
    ("0.5", |s| s.p50_ns),
    ("0.95", |s| s.p95_ns),
    ("0.99", |s| s.p99_ns),
];

/// Exposition writer: families declare HELP/TYPE once, samples append
/// under them.
struct Prom {
    out: String,
}

impl Prom {
    fn new() -> Prom {
        Prom { out: String::with_capacity(8192) }
    }

    fn family(&mut self, name: &str, ty: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(ty);
        self.out.push('\n');
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                for c in v.chars() {
                    match c {
                        '\\' => self.out.push_str("\\\\"),
                        '"' => self.out.push_str("\\\""),
                        '\n' => self.out.push_str("\\n"),
                        c => self.out.push(c),
                    }
                }
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        if value.fract() == 0.0 && value.abs() < 9e15 {
            self.out.push_str(&format!("{}", value as i64));
        } else {
            self.out.push_str(&format!("{value}"));
        }
        self.out.push('\n');
    }

    fn finish(mut self) -> String {
        self.out.push_str("# EOF");
        self.out
    }
}

/// Render the full exposition for a set of engine scopes plus the
/// (optional) replication snapshot. Single-engine serves pass one
/// unlabelled scope; `--tenants` serves pass one scope per tenant.
/// The returned text ends with the `# EOF` line (no trailing newline).
pub fn render(scopes: &[Scope<'_>], repl: Option<&ReplSnapshot>) -> String {
    let mut p = Prom::new();
    let tenants_mode = scopes.iter().any(|s| s.tenant.is_some());

    // Label plumbing: `with` prefixes the scope's tenant label.
    fn with<'a>(
        scope: &'a Scope<'_>,
        extra: &[(&'a str, &'a str)],
    ) -> Vec<(&'a str, &'a str)> {
        let mut labels = Vec::with_capacity(extra.len() + 1);
        if let Some(t) = &scope.tenant {
            labels.push(("tenant", t.name.as_str()));
        }
        labels.extend_from_slice(extra);
        labels
    }

    // --- engine counters ---------------------------------------------------
    let counters: [(&str, &str, fn(&EngineStats) -> f64); 10] = [
        ("fast_requests_submitted_total", "Update requests admitted", |s| s.submitted as f64),
        ("fast_requests_completed_total", "Update requests committed", |s| s.completed as f64),
        ("fast_requests_rejected_total", "Admissions rejected (backpressure)", |s| {
            s.rejected as f64
        }),
        ("fast_batches_sealed_total", "Group-commit batches sealed", |s| s.batches as f64),
        ("fast_rows_updated_total", "Distinct rows written by sealed batches", |s| {
            s.rows_updated as f64
        }),
        ("fast_coalesce_hits_total", "Requests coalesced into an already-touched row", |s| {
            s.shards.iter().map(|sh| sh.coalesce_hits).sum::<u64>() as f64
        }),
        ("fast_tickets_resolved_total", "Completion tickets resolved", |s| {
            s.tickets_resolved as f64
        }),
        ("fast_queries_total", "In-array shard queries answered", |s| s.queries as f64),
        ("fast_modeled_ns_total", "Modeled macro time (ns)", |s| s.modeled_ns),
        ("fast_modeled_energy_pj_total", "Modeled macro energy (pJ)", |s| s.modeled_energy_pj),
    ];
    for (name, help, get) in counters {
        p.family(name, "counter", help);
        for scope in scopes {
            p.sample(name, &with(scope, &[]), get(scope.stats));
        }
    }

    p.family("fast_backend_info", "Engine backend (constant 1, backend in the label)", "gauge");
    for scope in scopes {
        p.sample("fast_backend_info", &with(scope, &[("backend", scope.stats.backend)]), 1.0);
    }

    // --- seal reasons ------------------------------------------------------
    p.family("fast_seal_total", "counter", "Batch seals by reason");
    for scope in scopes {
        let s = scope.stats;
        let reasons = [
            ("full", s.shards.iter().map(|sh| sh.sealed_full).sum::<u64>()),
            ("kind_change", s.shards.iter().map(|sh| sh.sealed_kind_change).sum::<u64>()),
            ("deadline", s.shards.iter().map(|sh| sh.sealed_deadline).sum::<u64>()),
            ("forced", s.shards.iter().map(|sh| sh.sealed_forced).sum::<u64>()),
        ];
        for (reason, n) in reasons {
            p.sample("fast_seal_total", &with(scope, &[("reason", reason)]), n as f64);
        }
    }

    // --- contention --------------------------------------------------------
    p.family("fast_submit_spins_total", "counter", "Spin probes burned by blocking submits");
    for scope in scopes {
        p.sample("fast_submit_spins_total", &with(scope, &[]), scope.stats.submit_spins as f64);
    }
    p.family("fast_park_events_total", "counter", "Blocking submits that parked");
    for scope in scopes {
        p.sample("fast_park_events_total", &with(scope, &[]), scope.stats.park_events as f64);
    }

    // --- per-shard gauges --------------------------------------------------
    let gauges: [(&str, &str, fn(&crate::metrics::ShardSnapshot) -> u64); 3] = [
        ("fast_queue_depth", "Commands admitted but not yet drained", |sh| sh.queue_depth),
        ("fast_queue_high_water", "Peak queue occupancy", |sh| sh.queue_high_water),
        ("fast_commit_seq", "Last committed sequence number", |sh| sh.commit_seq),
    ];
    for (name, help, get) in gauges {
        p.family(name, "gauge", help);
        for scope in scopes {
            for (i, sh) in scope.stats.shards.iter().enumerate() {
                let shard = i.to_string();
                p.sample(name, &with(scope, &[("shard", shard.as_str())]), get(sh) as f64);
            }
        }
    }

    // --- latency summaries -------------------------------------------------
    p.family("fast_apply_wall_ns", "summary", "Backend batch-apply wall clock (ns)");
    for scope in scopes {
        summary(&mut p, "fast_apply_wall_ns", &with(scope, &[]), &scope.stats.apply_wall);
    }
    let shard_summaries: [(&str, &str, fn(&crate::metrics::ShardSnapshot) -> LatencySummary); 4] = [
        ("fast_commit_wall_ns", "Submit to ticket-resolve wall clock (ns)", |sh| sh.commit_wall),
        ("fast_commit_modeled_ns", "Modeled latency of committing batches (ns)", |sh| {
            sh.commit_modeled
        }),
        ("fast_query_wall_ns", "Query execution wall clock (ns)", |sh| sh.query_wall),
        ("fast_wake_batch", "Ticket waiters woken per seal (count, not ns)", |sh| sh.wake_batch),
    ];
    for (name, help, get) in shard_summaries {
        p.family(name, "summary", help);
        for scope in scopes {
            for (i, sh) in scope.stats.shards.iter().enumerate() {
                let shard = i.to_string();
                summary(&mut p, name, &with(scope, &[("shard", shard.as_str())]), &get(sh));
            }
        }
    }

    // --- WAL ---------------------------------------------------------------
    let wal: [(&str, &str, fn(&crate::metrics::ShardSnapshot) -> u64); 6] = [
        ("fast_wal_records_total", "WAL records appended", |sh| sh.wal_records),
        ("fast_wal_bytes_total", "WAL bytes appended", |sh| sh.wal_bytes),
        ("fast_wal_fsyncs_total", "fsyncs issued", |sh| sh.wal_fsyncs),
        ("fast_wal_rotations_total", "Segment rotations", |sh| sh.wal_rotations),
        ("fast_wal_coalesced_writes_total", "Writes carrying >= 2 coalesced frames", |sh| {
            sh.wal_coalesced_writes
        }),
        ("fast_wal_coalesced_frames_total", "Frames delivered by coalesced writes", |sh| {
            sh.wal_coalesced_frames
        }),
    ];
    for (name, help, get) in wal {
        p.family(name, "counter", help);
        for scope in scopes {
            let total: u64 = scope.stats.shards.iter().map(get).sum();
            p.sample(name, &with(scope, &[]), total as f64);
        }
    }
    p.family("fast_wal_fsync_ns", "summary", "fsync call latency (ns)");
    for scope in scopes {
        for (i, sh) in scope.stats.shards.iter().enumerate() {
            let shard = i.to_string();
            summary(
                &mut p,
                "fast_wal_fsync_ns",
                &with(scope, &[("shard", shard.as_str())]),
                &sh.wal_fsync,
            );
        }
    }

    // --- replication (always emitted; zeros without a role) ----------------
    let zero = ReplSnapshot {
        role: "none",
        epoch: 0,
        connected: false,
        reconnects: 0,
        frames_applied: 0,
        dup_frames: 0,
        wire_errors: 0,
        digests_verified: 0,
        failed: None,
        shards: Vec::new(),
    };
    let r = repl.unwrap_or(&zero);
    p.family("fast_repl_epoch", "gauge", "Replication epoch (fencing token)");
    p.sample("fast_repl_epoch", &[("role", r.role)], r.epoch as f64);
    p.family("fast_repl_connected", "gauge", "1 when the follower link is up");
    p.sample("fast_repl_connected", &[], if r.connected { 1.0 } else { 0.0 });
    p.family("fast_repl_failed", "gauge", "1 when replication fail-stopped on divergence");
    p.sample("fast_repl_failed", &[], if r.failed.is_some() { 1.0 } else { 0.0 });
    let repl_counters: [(&str, &str, u64); 5] = [
        ("fast_repl_reconnects_total", "Follower reconnect attempts", r.reconnects),
        ("fast_repl_frames_applied_total", "Replicated WAL frames applied", r.frames_applied),
        ("fast_repl_dup_frames_total", "Duplicate frames skipped on resume", r.dup_frames),
        ("fast_repl_wire_errors_total", "Transient wire errors", r.wire_errors),
        ("fast_repl_digests_verified_total", "Segment digests verified", r.digests_verified),
    ];
    for (name, help, v) in repl_counters {
        p.family(name, "counter", help);
        p.sample(name, &[], v as f64);
    }
    p.family("fast_repl_lag_lsn", "gauge", "Primary tail minus applied LSN, per shard");
    if r.shards.is_empty() {
        p.sample("fast_repl_lag_lsn", &[], 0.0);
    } else {
        for sh in &r.shards {
            let shard = sh.shard.to_string();
            p.sample("fast_repl_lag_lsn", &[("shard", shard.as_str())], sh.lag_lsn as f64);
        }
    }

    // --- span tracing ------------------------------------------------------
    p.family("fast_spans_sampled_total", "counter", "Request spans sampled at admission");
    for scope in scopes {
        let v = scope.tel.map(|t| t.spans_sampled).unwrap_or(0);
        p.sample("fast_spans_sampled_total", &with(scope, &[]), v as f64);
    }
    p.family("fast_spans_dropped_total", "counter", "Completed spans dropped on full rings");
    for scope in scopes {
        let v = scope.tel.map(|t| t.spans_dropped).unwrap_or(0);
        p.sample("fast_spans_dropped_total", &with(scope, &[]), v as f64);
    }
    p.family("fast_span_stage_ns", "summary", "Per-stage span latency (ns)");
    for scope in scopes {
        if let Some(tel) = scope.tel {
            for (stage, s) in &tel.stages {
                summary(&mut p, "fast_span_stage_ns", &with(scope, &[("stage", stage)]), s);
            }
        }
    }
    p.family("fast_ops_per_sec", "gauge", "Completed requests per second (series window)");
    for scope in scopes {
        let v = scope.tel.map(|t| t.ops_per_sec).unwrap_or(0.0);
        p.sample("fast_ops_per_sec", &with(scope, &[]), v);
    }
    p.family("fast_wal_bytes_per_sec", "gauge", "WAL append rate (series window)");
    for scope in scopes {
        let v = scope.tel.map(|t| t.wal_bytes_per_sec).unwrap_or(0.0);
        p.sample("fast_wal_bytes_per_sec", &with(scope, &[]), v);
    }

    // --- tenant metadata ---------------------------------------------------
    if tenants_mode {
        p.family("fast_tenants", "gauge", "Tenants registered");
        p.sample("fast_tenants", &[], scopes.len() as f64);
        let meta: [(&str, &str, fn(&TenantMeta) -> usize); 3] = [
            ("fast_tenant_rows", "Tenant logical rows", |t| t.rows),
            ("fast_tenant_quota_rows", "Tenant row quota", |t| t.quota_rows),
            ("fast_tenant_q", "Tenant word width (bits)", |t| t.q),
        ];
        for (name, help, get) in meta {
            p.family(name, "gauge", help);
            for scope in scopes {
                if let Some(t) = &scope.tenant {
                    p.sample(name, &[("tenant", t.name.as_str())], get(t) as f64);
                }
            }
        }
    }

    p.finish()
}

fn summary(p: &mut Prom, name: &str, labels: &[(&str, &str)], s: &LatencySummary) {
    for (q, get) in QUANTILES {
        let mut l = labels.to_vec();
        l.push(("quantile", q));
        p.sample(name, &l, get(s) as f64);
    }
    p.sample(&format!("{name}_count"), labels, s.count as f64);
    p.sample(&format!("{name}_sum"), labels, s.mean_ns * s.count as f64);
}

// ---------------------------------------------------------------------------
// Parser (shared by the round-trip tests and `fast stats --connect`).
// ---------------------------------------------------------------------------

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// A parsed scrape: the family TYPE declarations plus every sample.
#[derive(Debug, Clone, Default)]
pub struct Scrape {
    /// family name -> declared type (counter|gauge|summary).
    pub types: BTreeMap<String, String>,
    pub samples: Vec<Sample>,
}

impl Scrape {
    /// Family presence = a `# TYPE` declaration was seen.
    pub fn has_family(&self, family: &str) -> bool {
        self.types.contains_key(family)
    }

    /// Sum of every sample with exactly this name (label-agnostic).
    pub fn total(&self, name: &str) -> f64 {
        self.samples.iter().filter(|s| s.name == name).map(|s| s.value).sum()
    }

    /// First sample whose name matches and whose labels are a superset
    /// of `labels`.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| {
                s.name == name
                    && labels.iter().all(|(k, v)| {
                        s.labels.iter().any(|(sk, sv)| sk == k && sv == v)
                    })
            })
            .map(|s| s.value)
    }
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .enumerate()
            .all(|(i, c)| c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit()))
}

/// Parse Prometheus text exposition. Strict about the subset we emit:
/// unknown comment kinds are skipped, malformed sample lines are
/// errors (the tests lean on this for "well-formed").
pub fn parse_text(text: &str) -> Result<Scrape> {
    let mut out = Scrape::default();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            if rest == "EOF" {
                break;
            }
            if let Some(decl) = rest.strip_prefix("TYPE ") {
                let mut it = decl.splitn(2, ' ');
                let name = it.next().unwrap_or("");
                let ty = it.next().unwrap_or("");
                if !valid_name(name) || !matches!(ty, "counter" | "gauge" | "summary") {
                    bail!("line {}: malformed TYPE declaration: {line:?}", lineno + 1);
                }
                out.types.insert(name.to_string(), ty.to_string());
            }
            // HELP and other comments: free text, skipped.
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        out.samples.push(parse_sample(line).with_context(|| format!("line {}", lineno + 1))?);
    }
    Ok(out)
}

fn parse_sample(line: &str) -> Result<Sample> {
    let (name_labels, value) = match line.rfind(' ') {
        Some(i) => (&line[..i], &line[i + 1..]),
        None => bail!("sample line has no value: {line:?}"),
    };
    let value: f64 = value.parse().with_context(|| format!("bad sample value in {line:?}"))?;
    let (name, labels) = match name_labels.find('{') {
        None => (name_labels.to_string(), Vec::new()),
        Some(open) => {
            let name = name_labels[..open].to_string();
            let body = name_labels[open + 1..]
                .strip_suffix('}')
                .with_context(|| format!("unterminated label set in {line:?}"))?;
            (name, parse_labels(body)?)
        }
    };
    if !valid_name(&name) {
        bail!("bad metric name in {line:?}");
    }
    Ok(Sample { name, labels, value })
}

fn parse_labels(body: &str) -> Result<Vec<(String, String)>> {
    let mut labels = Vec::new();
    let mut chars = body.chars().peekable();
    loop {
        let mut key = String::new();
        while let Some(&c) = chars.peek() {
            if c == '=' {
                break;
            }
            key.push(c);
            chars.next();
        }
        if key.is_empty() {
            bail!("empty label key in {body:?}");
        }
        if chars.next() != Some('=') || chars.next() != Some('"') {
            bail!("label {key:?} not followed by =\" in {body:?}");
        }
        let mut val = String::new();
        loop {
            match chars.next() {
                Some('\\') => match chars.next() {
                    Some('\\') => val.push('\\'),
                    Some('"') => val.push('"'),
                    Some('n') => val.push('\n'),
                    other => bail!("bad escape {other:?} in {body:?}"),
                },
                Some('"') => break,
                Some(c) => val.push(c),
                None => bail!("unterminated label value in {body:?}"),
            }
        }
        labels.push((key, val));
        match chars.next() {
            Some(',') => continue,
            None => break,
            other => bail!("junk {other:?} after label value in {body:?}"),
        }
    }
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ShardSnapshot;
    use crate::telemetry::{Telemetry, TelemetryConfig};

    fn fake_stats(shards: usize) -> EngineStats {
        EngineStats {
            submitted: 100,
            completed: 90,
            rejected: 2,
            batches: 10,
            rows_updated: 80,
            rows_per_batch: 8.0,
            modeled_ns: 1234.5,
            modeled_energy_pj: 6.75,
            apply_wall: LatencySummary::default(),
            backend: "fast-behavioural",
            queue_depth: 3,
            tickets_resolved: 40,
            queries: 2,
            submit_spins: 7,
            park_events: 1,
            wal_coalesced_writes: 4,
            wal_coalesced_frames: 12,
            shards: (0..shards)
                .map(|i| ShardSnapshot {
                    requests: 50,
                    sealed_full: 2,
                    sealed_deadline: 3,
                    wal_records: 5 + i as u64,
                    wal_bytes: 100,
                    ..ShardSnapshot::default()
                })
                .collect(),
        }
    }

    #[test]
    fn render_parse_round_trip_covers_every_documented_family() {
        let stats = fake_stats(2);
        let tel = Telemetry::new(TelemetryConfig::default(), 2);
        let snap = tel.snapshot();
        let text = render(&[Scope { tenant: None, stats: &stats, tel: Some(&snap) }], None);
        assert!(text.ends_with("# EOF"), "must terminate with EOF marker");
        let scrape = parse_text(&text).unwrap();
        for family in DOCUMENTED_FAMILIES {
            assert!(scrape.has_family(family), "family {family} missing from exposition");
        }
        // Values survive the trip.
        assert_eq!(scrape.total("fast_requests_submitted_total"), 100.0);
        assert_eq!(scrape.total("fast_requests_completed_total"), 90.0);
        assert_eq!(scrape.value("fast_seal_total", &[("reason", "full")]), Some(4.0));
        assert_eq!(scrape.value("fast_queue_depth", &[("shard", "1")]), Some(0.0));
        assert_eq!(scrape.total("fast_wal_records_total"), 11.0);
        // Repl families are present (zeros) without a repl role.
        assert_eq!(scrape.total("fast_repl_epoch"), 0.0);
        assert_eq!(scrape.total("fast_repl_lag_lsn"), 0.0);
        // No tenant families on a single-engine scrape.
        assert!(!scrape.has_family("fast_tenants"));
    }

    #[test]
    fn tenant_scopes_label_every_series_and_add_tenant_families() {
        let a = fake_stats(1);
        let b = fake_stats(1);
        let text = render(
            &[
                Scope {
                    tenant: Some(TenantMeta {
                        name: "db".into(),
                        rows: 64,
                        q: 4,
                        quota_rows: 64,
                    }),
                    stats: &a,
                    tel: None,
                },
                Scope {
                    tenant: Some(TenantMeta {
                        name: "nn".into(),
                        rows: 32,
                        q: 16,
                        quota_rows: 8,
                    }),
                    stats: &b,
                    tel: None,
                },
            ],
            None,
        );
        let scrape = parse_text(&text).unwrap();
        for family in TENANT_FAMILIES {
            assert!(scrape.has_family(family), "family {family} missing in tenants mode");
        }
        assert_eq!(scrape.total("fast_tenants"), 2.0);
        assert_eq!(scrape.value("fast_tenant_q", &[("tenant", "nn")]), Some(16.0));
        assert_eq!(
            scrape.value("fast_requests_completed_total", &[("tenant", "db")]),
            Some(90.0)
        );
        // Engine families are still present (tenant-labelled).
        for family in DOCUMENTED_FAMILIES {
            assert!(scrape.has_family(family), "family {family} missing in tenants mode");
        }
    }

    #[test]
    fn repl_snapshot_fills_the_repl_families() {
        use crate::replication::ReplStats;
        let stats = fake_stats(2);
        let rs = ReplStats::new("follower", 2);
        rs.record_applied(0, 5);
        rs.record_primary_tail(0, 9);
        let snap = rs.snapshot();
        let text =
            render(&[Scope { tenant: None, stats: &stats, tel: None }], Some(&snap));
        let scrape = parse_text(&text).unwrap();
        assert_eq!(scrape.value("fast_repl_lag_lsn", &[("shard", "0")]), Some(4.0));
        assert_eq!(scrape.value("fast_repl_epoch", &[("role", "follower")]), Some(0.0));
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        for bad in [
            "fast_x",                       // no value
            "fast_x notanumber",            // bad value
            "fast_x{a=\"b\" 1",             // unterminated labels
            "fast_x{=\"b\"} 1",             // empty key
            "fast_x{a=\"b} 1",              // unterminated value... parses as label chars
            "9bad_name 1",                  // bad name
        ] {
            assert!(parse_text(bad).is_err(), "{bad:?} should fail");
        }
        // The escapes we emit round-trip.
        let s = parse_text("fast_x{a=\"q\\\"uo\\\\te\\n\"} 2.5").unwrap();
        assert_eq!(s.samples[0].labels[0].1, "q\"uo\\te\n");
        assert_eq!(s.samples[0].value, 2.5);
    }
}
