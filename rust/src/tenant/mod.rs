//! Multi-tenant namespaces (column families) over the update engine.
//!
//! One `fast serve --tenants` process hosts any number of **named
//! tenants**, each an isolated row space with its own bit-precision
//! `q ∈ {4, 8, 16}` — the reconfigurable-precision CiM knob: a 4-bit
//! tenant's plane stack is 4 bitplanes deep, so its batches execute in
//! O(4·rows/64) word ops on the bitplane tier where an 8-bit tenant
//! pays O(8·rows/64) and a 16-bit one O(16·rows/64).
//!
//! ## Architecture
//!
//! A [`TenantRegistry`] maps tenant name → [`TenantHandle`], and each
//! handle owns a full `UpdateEngine` built by a caller-supplied
//! factory (the CLI's backend/fidelity/seal flags apply uniformly;
//! rows and q come from the tenant's [`TenantSpec`]). Isolation and
//! fairness are therefore **structural**, not scheduled: every tenant
//! has its own shard workers, bounded admission queues, plane stacks,
//! commit sequences, and WAL subdirectory — a hot tenant saturating
//! its queues backpressures *its own* producers (`ERR busy`) while a
//! cold tenant's tickets keep resolving within its own seal deadline.
//! The fairness bound is exactly the engine's group-commit bound: a
//! cold tenant's commit latency is independent of any other tenant's
//! backlog (asserted by `rust/tests/integration_tenants.rs`).
//!
//! ## Row quotas (`ERR quota`)
//!
//! A tenant's row space is `spec.rows`, but admission is capped at
//! `spec.quota_rows <= rows`: any update/write addressing a row at or
//! beyond the quota is rejected with a typed [`QuotaExceeded`] root
//! cause *before* it reaches the engine, which the serve protocol
//! answers as `ERR quota …`. Like `ERR busy` — and unlike terminal
//! `ERR`s — it is retryable: an operator can recreate the tenant with
//! a larger quota without restarting the server, and clients keep the
//! connection.
//!
//! ## Durability layout
//!
//! With a WAL root, the registry persists `tenants.json` (atomic
//! temp+rename manifest of every tenant's spec) in the root and gives
//! each tenant the standard durable engine directory at
//! `<root>/tenants/<name>/` — per-shard segmented WAL, snapshots,
//! single-writer lock, torn-tail repair all ride the existing
//! `durability` machinery unchanged. Opening the registry on a root
//! recovers **every** manifest tenant before any traffic (each
//! engine's recovery runs inside its `UpdateEngine::start`).
//!
//! ## Per-tenant cost closed forms
//!
//! All accounting stays per tenant because the engines are disjoint.
//! For a tenant of precision `q` (one `q`-bit segment per row), the
//! bitplane tier's update closed form (see `fastmem::bitplane`)
//! specializes to:
//!
//! ```text
//! plane count  = q                      (bitplanes per segment)
//! plane words  = q · ceil(rows/64)      (u64 lanes touched per batch)
//! cycles       = q                      (max segment width)
//! alu_evals    = q · enabled_rows
//! cell_toggles = 2·[ Σ_{j<q-1} (j+1)·cnt(V_j⊕V_{j+1})
//!                  + q·cnt(V_{q-1}⊕R_0)
//!                  + Σ_{k<q-1} (q-1-k)·cnt(R_k⊕R_{k+1}) ]
//! ```
//!
//! so a 4-bit tenant's modeled batch cycles are exactly 4/8 of an
//! 8-bit tenant's and 4/16 of a 16-bit tenant's for the same row set
//! — the "measurably below" acceptance bar is a closed-form identity,
//! asserted per tenant by the integration net.

mod registry;

pub use registry::{tenant_dir, TenantHandle, TenantRegistry};

use anyhow::{bail, ensure};

use crate::Result;

/// The bit precisions a tenant may choose (the reconfigurable-
/// precision knob). Narrower q ⇒ proportionally shallower plane
/// stacks ⇒ proportionally faster plane-wise batches.
pub const ALLOWED_Q: [usize; 3] = [4, 8, 16];

/// Longest tenant name the registry accepts (names become directory
/// components under `<root>/tenants/`).
pub const MAX_NAME_LEN: usize = 32;

/// One tenant's identity and shape. Immutable once created; `drop` +
/// `create` is the resize path (the WAL subdirectory is removed with
/// the tenant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSpec {
    /// Registry key and WAL subdirectory name
    /// (`[a-z0-9_-]`, starts alphanumeric, at most [`MAX_NAME_LEN`]).
    pub name: String,
    /// Row-space size (the engine's `rows`; must divide by the
    /// registry's shard count).
    pub rows: usize,
    /// Bit precision, one of [`ALLOWED_Q`].
    pub q: usize,
    /// Admission quota: rows `>= quota_rows` answer typed
    /// [`QuotaExceeded`] (`ERR quota` on the wire). Defaults to
    /// `rows` (the whole slice is admissible).
    pub quota_rows: usize,
}

impl TenantSpec {
    /// A spec with the quota covering the whole row space.
    pub fn new(name: &str, rows: usize, q: usize) -> Result<TenantSpec> {
        Self::with_quota(name, rows, q, rows)
    }

    /// A spec with an explicit admission quota (`quota_rows <= rows`).
    pub fn with_quota(name: &str, rows: usize, q: usize, quota_rows: usize) -> Result<TenantSpec> {
        let spec = TenantSpec { name: name.to_string(), rows, q, quota_rows };
        spec.validate()?;
        Ok(spec)
    }

    /// Validate every field (names double as directory components, so
    /// the character set is strict).
    pub fn validate(&self) -> Result<()> {
        validate_name(&self.name)?;
        ensure!(self.rows >= 1, "tenant {:?}: rows must be >= 1", self.name);
        ensure!(
            ALLOWED_Q.contains(&self.q),
            "tenant {:?}: q {} is not one of the reconfigurable precisions {:?}",
            self.name,
            self.q,
            ALLOWED_Q
        );
        ensure!(
            self.quota_rows >= 1 && self.quota_rows <= self.rows,
            "tenant {:?}: quota_rows {} must be in 1..={}",
            self.name,
            self.quota_rows,
            self.rows
        );
        Ok(())
    }

    /// Bitplanes a batch touches on the bitplane tier (one segment of
    /// width q per row ⇒ q planes).
    pub fn plane_count(&self) -> usize {
        self.q
    }

    /// u64 plane words one batch sweeps on the bitplane tier:
    /// `q · ceil(rows/64)` — the O(q·rows/64) closed form narrow
    /// tenants win by.
    pub fn plane_words(&self) -> usize {
        self.q * self.rows.div_ceil(64)
    }
}

/// Is `name` a valid tenant name? Strict because names become wire
/// tokens, JSON values, and directory components.
pub fn validate_name(name: &str) -> Result<()> {
    ensure!(!name.is_empty(), "tenant name must not be empty");
    ensure!(
        name.len() <= MAX_NAME_LEN,
        "tenant name {name:?} exceeds {MAX_NAME_LEN} characters"
    );
    let mut chars = name.chars();
    let first = chars.next().expect("non-empty");
    ensure!(
        first.is_ascii_lowercase() || first.is_ascii_digit(),
        "tenant name {name:?} must start with [a-z0-9]"
    );
    for c in name.chars() {
        if !(c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-') {
            bail!("tenant name {name:?} contains {c:?} (allowed: [a-z0-9_-])");
        }
    }
    Ok(())
}

/// Typed over-admission error: a request addressed a row at or beyond
/// the tenant's `quota_rows`. Carried as the root cause of the
/// `anyhow` error the tenant submit paths return, so the serve
/// protocol can answer a retryable `ERR quota …` (like `ERR busy`,
/// unlike terminal errors):
/// `err.root_cause().downcast_ref::<QuotaExceeded>().is_some()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuotaExceeded {
    pub tenant: String,
    pub row: usize,
    pub quota_rows: usize,
}

impl std::fmt::Display for QuotaExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tenant {:?}: row {} is over the admission quota of {} row(s) \
             (retryable: recreate the tenant with a larger quota)",
            self.tenant, self.row, self.quota_rows
        )
    }
}

impl std::error::Error for QuotaExceeded {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validation_accepts_the_documented_shapes() {
        for q in ALLOWED_Q {
            let s = TenantSpec::new("t0", 128, q).unwrap();
            assert_eq!(s.quota_rows, 128);
            assert_eq!(s.plane_count(), q);
            assert_eq!(s.plane_words(), q * 2);
        }
        let s = TenantSpec::with_quota("a-b_9", 64, 8, 10).unwrap();
        assert_eq!(s.quota_rows, 10);
    }

    #[test]
    fn spec_validation_rejects_bad_shapes() {
        assert!(TenantSpec::new("t", 128, 5).is_err(), "q not in {ALLOWED_Q:?}");
        assert!(TenantSpec::new("t", 0, 8).is_err(), "zero rows");
        assert!(TenantSpec::with_quota("t", 64, 8, 0).is_err(), "zero quota");
        assert!(TenantSpec::with_quota("t", 64, 8, 65).is_err(), "quota > rows");
    }

    #[test]
    fn name_validation_is_strict() {
        for ok in ["a", "db_2024", "nn-weights", "0x", &"a".repeat(MAX_NAME_LEN)] {
            assert!(validate_name(ok).is_ok(), "{ok:?}");
        }
        for bad in ["", "A", "has space", "..", "a/b", "-leading", "_leading", "é", &"a".repeat(MAX_NAME_LEN + 1)]
        {
            assert!(validate_name(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn quota_error_is_a_typed_root_cause() {
        let e = anyhow::Error::new(QuotaExceeded {
            tenant: "t".into(),
            row: 99,
            quota_rows: 64,
        });
        assert!(e.root_cause().downcast_ref::<QuotaExceeded>().is_some());
        let msg = format!("{e:#}");
        assert!(msg.contains("quota") && msg.contains("99"), "{msg}");
    }
}
