//! The tenant registry: name → engine routing, quota admission, the
//! persistent `tenants.json` manifest, and per-tenant WAL
//! subdirectories. See the module docs of [`crate::tenant`] for the
//! architecture and the isolation/fairness argument.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context};

use super::{QuotaExceeded, TenantSpec};
use crate::apps::trace::state_digest;
use crate::coordinator::{EngineStats, Ticket, UpdateEngine, UpdateRequest};
use crate::util::json::Json;
use crate::Result;

/// Builds one engine per tenant, from the tenant's shape (rows, q)
/// plus whatever backend/fidelity/seal/durability policy the caller
/// closed over. Invoked under the registry lock, so creation is
/// atomic with manifest persistence.
pub type TenantFactory = dyn Fn(&TenantSpec) -> Result<UpdateEngine> + Send + Sync;

/// Manifest file name, kept directly in the registry root (next to
/// the `tenants/` subdirectory tree).
const MANIFEST: &str = "tenants.json";

/// How long [`TenantRegistry::drop_tenant`] waits for in-flight
/// protocol sessions to release their handle clones before giving up.
const DROP_HANDLE_WAIT: Duration = Duration::from_secs(5);

/// One live tenant: its spec and its private engine. Mutating entry
/// points go through the quota-checked wrappers; read-side entry
/// points ([`Self::engine`]) hit the engine directly.
pub struct TenantHandle {
    spec: TenantSpec,
    engine: UpdateEngine,
}

impl TenantHandle {
    pub fn spec(&self) -> &TenantSpec {
        &self.spec
    }

    /// The tenant's engine, for read-side verbs (READ/WAIT/DRAIN/
    /// DIGEST/QRY/STATS) and tests. Updates and writes should go
    /// through [`Self::submit`]/[`Self::submit_ticketed`]/
    /// [`Self::write`] so the admission quota applies.
    pub fn engine(&self) -> &UpdateEngine {
        &self.engine
    }

    /// Typed quota gate: rows at or beyond `quota_rows` never reach
    /// the engine.
    fn admit(&self, row: usize) -> Result<()> {
        if row >= self.spec.quota_rows {
            return Err(anyhow::Error::new(QuotaExceeded {
                tenant: self.spec.name.clone(),
                row,
                quota_rows: self.spec.quota_rows,
            }));
        }
        Ok(())
    }

    /// Quota-checked fire-and-forget submit.
    pub fn submit(&self, req: UpdateRequest) -> Result<()> {
        self.admit(req.row)?;
        self.engine.submit(req)
    }

    /// Quota-checked ticketed submit.
    pub fn submit_ticketed(&self, req: UpdateRequest) -> Result<Ticket> {
        self.admit(req.row)?;
        self.engine.submit_ticketed(req)
    }

    /// Quota-checked conventional-port write.
    pub fn write(&self, row: usize, value: u32) -> Result<()> {
        self.admit(row)?;
        self.engine.write(row, value)
    }

    /// FNV-1a fingerprint of this tenant's row state (the per-tenant
    /// `DIGEST`).
    pub fn digest(&self) -> Result<u64> {
        Ok(state_digest(&self.engine.snapshot()?))
    }

    fn into_engine(self) -> UpdateEngine {
        self.engine
    }
}

/// Name → tenant map plus the construction/persistence policy. Shared
/// across protocol sessions as `Arc<TenantRegistry>`; every method is
/// `&self`.
pub struct TenantRegistry {
    tenants: Mutex<BTreeMap<String, Arc<TenantHandle>>>,
    factory: Box<TenantFactory>,
    root: Option<PathBuf>,
}

impl TenantRegistry {
    /// A volatile registry (no manifest, no WAL subdirectories) — the
    /// factory still decides each engine's backend and seal policy.
    pub fn volatile(
        factory: impl Fn(&TenantSpec) -> Result<UpdateEngine> + Send + Sync + 'static,
    ) -> TenantRegistry {
        TenantRegistry { tenants: Mutex::new(BTreeMap::new()), factory: Box::new(factory), root: None }
    }

    /// Open (or initialize) a durable registry rooted at `root`:
    /// every tenant in the manifest is rebuilt through the factory —
    /// whose engines, given a durability config at
    /// [`tenant_dir`]`(root, name)`, recover their WAL subdirectory
    /// before accepting work — so a restart restores every tenant.
    pub fn open(
        root: impl Into<PathBuf>,
        factory: impl Fn(&TenantSpec) -> Result<UpdateEngine> + Send + Sync + 'static,
    ) -> Result<TenantRegistry> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating tenant registry root {}", root.display()))?;
        let specs = load_manifest(&root.join(MANIFEST))?;
        let reg = TenantRegistry {
            tenants: Mutex::new(BTreeMap::new()),
            factory: Box::new(factory),
            root: Some(root),
        };
        {
            let mut map = reg.tenants.lock().expect("registry lock");
            for spec in specs {
                let engine = (reg.factory)(&spec)
                    .with_context(|| format!("recovering tenant {:?}", spec.name))?;
                map.insert(spec.name.clone(), Arc::new(TenantHandle { spec, engine }));
            }
        }
        Ok(reg)
    }

    /// The manifest/WAL root (`None` for a volatile registry).
    pub fn root(&self) -> Option<&Path> {
        self.root.as_deref()
    }

    /// Create a tenant: validates the spec, builds its engine, and
    /// (durable registries) persists the manifest atomically. Fails
    /// without side effects if the name exists.
    pub fn create(&self, spec: TenantSpec) -> Result<Arc<TenantHandle>> {
        spec.validate()?;
        let mut map = self.tenants.lock().expect("registry lock");
        ensure!(
            !map.contains_key(&spec.name),
            "tenant {:?} already exists (drop it first to reshape)",
            spec.name
        );
        let engine = (self.factory)(&spec)
            .with_context(|| format!("creating tenant {:?}", spec.name))?;
        let handle = Arc::new(TenantHandle { spec: spec.clone(), engine });
        map.insert(spec.name.clone(), Arc::clone(&handle));
        if let Err(e) = self.save_manifest(&map) {
            // Keep create atomic: roll the in-memory insert back so the
            // map never disagrees with the durable manifest.
            let h = map.remove(&spec.name).expect("just inserted");
            drop(map);
            let _ = shutdown_handle(h);
            return Err(e);
        }
        Ok(handle)
    }

    /// Drop a tenant: removed from the map and manifest first (no new
    /// routing), then its engine is drained and shut down, then its
    /// WAL subdirectory is deleted (destructive — `drop` + `create`
    /// is the resize path). Other tenants' engines are untouched.
    pub fn drop_tenant(&self, name: &str) -> Result<()> {
        let handle = {
            let mut map = self.tenants.lock().expect("registry lock");
            let handle = map
                .remove(name)
                .ok_or_else(|| anyhow!("unknown tenant {name:?}"))?;
            if let Err(e) = self.save_manifest(&map) {
                map.insert(name.to_string(), handle);
                return Err(e);
            }
            handle
        };
        shutdown_handle(handle).with_context(|| format!("shutting down tenant {name:?}"))?;
        if let Some(root) = &self.root {
            let dir = tenant_dir(root, name);
            if dir.exists() {
                std::fs::remove_dir_all(&dir)
                    .with_context(|| format!("removing tenant WAL dir {}", dir.display()))?;
            }
        }
        Ok(())
    }

    /// Look a tenant up by name.
    pub fn get(&self, name: &str) -> Result<Arc<TenantHandle>> {
        self.tenants
            .lock()
            .expect("registry lock")
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown tenant {name:?} (TENANT LIST shows the registry)"))
    }

    /// Every tenant's spec, name-sorted.
    pub fn list(&self) -> Vec<TenantSpec> {
        self.tenants
            .lock()
            .expect("registry lock")
            .values()
            .map(|h| h.spec.clone())
            .collect()
    }

    pub fn len(&self) -> usize {
        self.tenants.lock().expect("registry lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point-in-time stats for every tenant (the `--stats-json`
    /// per-tenant counters and latency histograms), name-sorted.
    pub fn stats(&self) -> Vec<(TenantSpec, EngineStats)> {
        let handles: Vec<Arc<TenantHandle>> =
            self.tenants.lock().expect("registry lock").values().cloned().collect();
        handles.iter().map(|h| (h.spec.clone(), h.engine.stats())).collect()
    }

    /// Every live tenant handle, name-sorted — the metrics exposition
    /// walks these to render one labelled scope per tenant (it needs
    /// the engine itself for the telemetry snapshot, not just
    /// [`Self::stats`]'s counters).
    pub fn handles(&self) -> Vec<Arc<TenantHandle>> {
        self.tenants.lock().expect("registry lock").values().cloned().collect()
    }

    /// Barrier over every tenant: drain all shards of all engines.
    pub fn drain_all(&self) -> Result<()> {
        let handles: Vec<Arc<TenantHandle>> =
            self.tenants.lock().expect("registry lock").values().cloned().collect();
        for h in handles {
            h.engine
                .drain_all()
                .with_context(|| format!("draining tenant {:?}", h.spec.name))?;
        }
        Ok(())
    }

    /// Clean shutdown of every tenant engine (WAL barriers included).
    /// Requires sole ownership of every handle, like
    /// `UpdateEngine::shutdown` requires sole ownership of the engine.
    pub fn shutdown(self) -> Result<()> {
        let map = self.tenants.into_inner().expect("registry lock");
        for (name, handle) in map {
            shutdown_handle(handle)
                .with_context(|| format!("shutting down tenant {name:?}"))?;
        }
        Ok(())
    }

    /// Atomic (temp + rename) manifest write, called under the map
    /// lock so the file always reflects a consistent registry state.
    fn save_manifest(&self, map: &BTreeMap<String, Arc<TenantHandle>>) -> Result<()> {
        let Some(root) = &self.root else { return Ok(()) };
        let mut body = String::from("{\"tenants\":[");
        for (i, h) in map.values().enumerate() {
            if i > 0 {
                body.push(',');
            }
            let s = &h.spec;
            body.push_str(&format!(
                "{{\"name\":\"{}\",\"rows\":{},\"q\":{},\"quota\":{}}}",
                s.name, s.rows, s.q, s.quota_rows
            ));
        }
        body.push_str("]}\n");
        let path = root.join(MANIFEST);
        let tmp = root.join(format!("{MANIFEST}.tmp"));
        std::fs::write(&tmp, body)
            .with_context(|| format!("writing tenant manifest {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing tenant manifest {}", path.display()))?;
        Ok(())
    }
}

/// A tenant's durable directory: `<root>/tenants/<name>/` — a
/// standard `durability` engine directory (per-shard WAL segments,
/// snapshots, single-writer lock).
pub fn tenant_dir(root: &Path, name: &str) -> PathBuf {
    root.join("tenants").join(name)
}

/// Wait (boundedly) for protocol sessions to release their clones of
/// the handle, then consume the engine and shut it down cleanly.
fn shutdown_handle(mut handle: Arc<TenantHandle>) -> Result<()> {
    let deadline = Instant::now() + DROP_HANDLE_WAIT;
    loop {
        match Arc::try_unwrap(handle) {
            Ok(inner) => return inner.into_engine().shutdown(),
            Err(back) => {
                ensure!(
                    Instant::now() < deadline,
                    "sessions still hold the tenant handle after {DROP_HANDLE_WAIT:?}"
                );
                handle = back;
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

/// Parse `tenants.json`. A missing file is an empty registry; a
/// malformed one is a hard error (refuse to guess at durable state).
fn load_manifest(path: &Path) -> Result<Vec<TenantSpec>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(anyhow!("reading tenant manifest {}: {e}", path.display())),
    };
    let v = Json::parse(&text)
        .with_context(|| format!("parsing tenant manifest {}", path.display()))?;
    let arr = v
        .get("tenants")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tenant manifest {}: missing \"tenants\" array", path.display()))?;
    let mut specs = Vec::with_capacity(arr.len());
    for t in arr {
        let name = t
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tenant manifest entry missing \"name\""))?;
        let field = |key: &str| {
            t.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("tenant {name:?}: manifest field {key:?} missing or not an integer"))
        };
        let spec = TenantSpec::with_quota(name, field("rows")?, field("q")?, field("quota")?)?;
        specs.push(spec);
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EngineConfig, FastBackend};

    fn volatile_registry() -> TenantRegistry {
        TenantRegistry::volatile(|spec: &TenantSpec| {
            let cfg = EngineConfig::new(spec.rows, spec.q);
            UpdateEngine::start(cfg, |p| Ok(Box::new(FastBackend::with_rows(p.rows, p.q))))
        })
    }

    #[test]
    fn create_route_drop_lifecycle() {
        let reg = volatile_registry();
        assert!(reg.is_empty());
        reg.create(TenantSpec::new("a", 64, 4).unwrap()).unwrap();
        reg.create(TenantSpec::new("b", 32, 16).unwrap()).unwrap();
        assert_eq!(reg.len(), 2);
        // Duplicate names refuse.
        let err = reg.create(TenantSpec::new("a", 16, 8).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("already exists"), "{err:#}");

        let a = reg.get("a").unwrap();
        a.write(3, 9).unwrap();
        a.engine().drain_all().unwrap();
        assert_eq!(a.engine().read(3).unwrap(), 9);
        // Tenants are disjoint row spaces.
        assert_eq!(reg.get("b").unwrap().engine().read(3).unwrap(), 0);

        drop(a);
        reg.drop_tenant("a").unwrap();
        assert!(reg.get("a").is_err());
        // The name is immediately reusable, fresh.
        let a2 = reg.create(TenantSpec::new("a", 64, 4).unwrap()).unwrap();
        assert_eq!(a2.engine().read(3).unwrap(), 0);
        drop(a2);
        reg.shutdown().unwrap();
    }

    #[test]
    fn quota_rejections_are_typed_and_precede_the_engine() {
        let reg = volatile_registry();
        let t = reg
            .create(TenantSpec::with_quota("q", 64, 8, 16).unwrap())
            .unwrap();
        t.submit(UpdateRequest::add(15, 1)).unwrap();
        for res in [
            t.submit(UpdateRequest::add(16, 1)).map(|_| ()),
            t.submit_ticketed(UpdateRequest::add(40, 1)).map(|_| ()),
            t.write(63, 5),
        ] {
            let e = res.unwrap_err();
            assert!(
                e.root_cause().downcast_ref::<QuotaExceeded>().is_some(),
                "{e:#}"
            );
        }
        // Nothing over-quota reached the engine.
        t.engine().drain_all().unwrap();
        assert_eq!(t.engine().read(16).unwrap(), 0);
        assert_eq!(t.engine().stats().submitted, 1);
        drop(t);
        reg.shutdown().unwrap();
    }

    #[test]
    fn manifest_round_trips_and_reopen_restores_every_tenant() {
        let root = std::env::temp_dir().join(format!("fast-tenant-reg-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let factory = |spec: &TenantSpec| {
            let cfg = EngineConfig::new(spec.rows, spec.q);
            UpdateEngine::start(cfg, |p| Ok(Box::new(FastBackend::with_rows(p.rows, p.q))))
        };
        let reg = TenantRegistry::open(&root, factory).unwrap();
        reg.create(TenantSpec::new("a", 64, 4).unwrap()).unwrap();
        reg.create(TenantSpec::with_quota("b", 32, 16, 8).unwrap()).unwrap();
        let listed = reg.list();
        reg.shutdown().unwrap();

        let reopened = TenantRegistry::open(&root, factory).unwrap();
        assert_eq!(reopened.list(), listed);
        reopened.drop_tenant("a").unwrap();
        reopened.shutdown().unwrap();

        let again = TenantRegistry::open(&root, factory).unwrap();
        assert_eq!(again.list().len(), 1);
        assert_eq!(again.list()[0].name, "b");
        again.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&root);
    }
}
