//! In-array query engine: batch reductions over the row space.
//!
//! The CiM literature around FAST is mostly about *computed reads* —
//! reductions evaluated inside the array rather than row-by-row over a
//! conventional port. This module adds that layer: `popcount`, `min`,
//! `max`, `range_count(lo, hi)`, `sum` and a masked `dot(broadcast_vec)`
//! over an enabled-row lane mask, each executable two ways:
//!
//! - **plane-wise** ([`plane_reduce`]) on the bit-plane tier: the
//!   reduction is evaluated from the bit planes directly (`cnt(·)` is
//!   `u64::count_ones` over lane words), touching `O(width · rows/64)`
//!   machine words instead of `O(rows)` decoded values;
//! - **scalar** ([`scalar_reduce`]) on the phase/word tiers and the
//!   digital baseline: one decoded word per row through the
//!   non-counting peek path, reduced on the host.
//!
//! Both paths return the same value AND the same [`BatchReport`]
//! accounting bit for bit — the differential property the query test
//! net (`rust/tests/integration_query.rs`) enforces across all four
//! backends against an independent host oracle.
//!
//! ## Cost closed form (documented like `bitplane.rs` does for updates)
//!
//! A reduction is one **non-destructive rotate-read pass**: every
//! enabled row circulates its `w`-bit segment once through the row ALU
//! (`w` shift cycles), the sense path taps the stream, and after `w`
//! cycles each cell holds its original bit again. Per enabled row `r`
//! with bits `b_0..b_{w-1}`, the cell at position `j` takes the values
//! `b_j, b_{j+1}, …` wrapping around — the full *circular* sequence —
//! so over the pass it toggles once per unequal adjacent pair in that
//! circular sequence: `T_r = Σ_j [b_j != b_{(j+1) mod w}]`, the same
//! count for every one of the `w` cells. With the update model's
//! factor 2 per toggle event (master+slave latch of the shift cell):
//!
//! ```text
//! cell_toggles = 2 · w · Σ_{enabled r} T_r
//!              = 2 · w · [ Σ_{j=0}^{w-2} cnt(V_j ⊕ V_{j+1})
//!                          + cnt(V_{w-1} ⊕ V_0) ]          (masked)
//! ```
//!
//! where `V_j` is bit-plane `j` and `cnt` the masked popcount — a
//! closed form from plane popcounts on the bit-plane tier, and the
//! per-row circular-transition count `T_r` on the scalar tiers, so the
//! two paths agree exactly. The other fields:
//!
//! ```text
//! cycles     = w                       (one rotation)
//! rows_active = |enabled rows|
//! alu_evals  = streams · w · |enabled| (streams = 2 for dot: the
//!                                       broadcast operand is a second
//!                                       bit stream through the ALU;
//!                                       1 for everything else)
//! ```
//!
//! Modeled energy mirrors the update path: each backend charges one
//! `FastModel::batch_op(rows_per_bank, q)` per bank containing an
//! enabled row (energy summed, latency maxed — banks are independent
//! arrays), so the engine's energy story extends to analytics with the
//! same exact cross-tier equality the update path has.

use anyhow::{anyhow, bail, ensure};

use crate::energy::{Cost, FastModel};
use crate::fastmem::{BatchReport, BitPlaneArray};
use crate::util::bits;
use crate::util::rng::Rng;
use crate::Result;

/// One reduction over the (masked) row space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Reduction {
    /// Total set bits over the enabled rows' segments.
    Popcount,
    /// Sum of the enabled rows' values (mod 2^64).
    Sum,
    /// Minimum enabled value; `mask(w)` when no row is enabled.
    Min,
    /// Maximum enabled value; `0` when no row is enabled.
    Max,
    /// Rows whose value lies in `[lo, hi]` (inclusive).
    RangeCount { lo: u32, hi: u32 },
    /// `Σ value[r] · vec[r]` over enabled rows (mod 2^64). One vector
    /// element per logical row, broadcast from outside the array.
    Dot { vec: Vec<u32> },
}

impl Reduction {
    pub fn name(&self) -> &'static str {
        match self {
            Reduction::Popcount => "popcount",
            Reduction::Sum => "sum",
            Reduction::Min => "min",
            Reduction::Max => "max",
            Reduction::RangeCount { .. } => "range",
            Reduction::Dot { .. } => "dot",
        }
    }

    /// Identity element for [`Self::combine`] at width `w`.
    pub fn identity(&self, w: usize) -> u64 {
        match self {
            Reduction::Min => u64::from(bits::mask(w)),
            _ => 0,
        }
    }

    /// Associative cross-shard (and cross-bank) combination of partial
    /// results: add for the counting/summing reductions, min/max for
    /// the order statistics.
    pub fn combine(&self, a: u64, b: u64) -> u64 {
        match self {
            Reduction::Min => a.min(b),
            Reduction::Max => a.max(b),
            _ => a.wrapping_add(b),
        }
    }

    /// Bit streams through the row ALU during the pass (`alu_evals`
    /// multiplier): 2 for dot (row + broadcast operand), 1 otherwise.
    pub fn streams(&self) -> u64 {
        match self {
            Reduction::Dot { .. } => 2,
            _ => 1,
        }
    }
}

/// A query: a reduction plus an optional enabled-row lane mask
/// (64 rows per `u64`, LSB-first — the [`BitPlaneArray`] lane layout).
/// `mask: None` enables every row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuerySpec {
    pub red: Reduction,
    pub mask: Option<Vec<u64>>,
}

impl QuerySpec {
    /// Query over every row.
    pub fn all(red: Reduction) -> Self {
        QuerySpec { red, mask: None }
    }

    /// Query over the rows enabled in `mask`.
    pub fn masked(red: Reduction, mask: Vec<u64>) -> Self {
        QuerySpec { red, mask: Some(mask) }
    }

    /// Shape/range validation against a `rows` × `w` target.
    pub fn validate(&self, rows: usize, w: usize) -> Result<()> {
        ensure!(rows >= 1, "query target has no rows");
        ensure!((1..=32).contains(&w), "query width {w} out of 1..=32");
        if let Some(m) = &self.mask {
            ensure!(
                m.len() == rows.div_ceil(64),
                "mask has {} lanes, rows {} need {}",
                m.len(),
                rows,
                rows.div_ceil(64)
            );
        }
        match &self.red {
            Reduction::RangeCount { lo, hi } => {
                ensure!(lo <= hi, "range lo {lo} > hi {hi}");
                ensure!(
                    *hi <= bits::mask(w),
                    "range hi {hi} exceeds {w}-bit max {}",
                    bits::mask(w)
                );
            }
            Reduction::Dot { vec } => {
                ensure!(
                    vec.len() == rows,
                    "dot vector has {} elements, target has {rows} rows",
                    vec.len()
                );
                for (r, &x) in vec.iter().enumerate() {
                    ensure!(
                        x <= bits::mask(w),
                        "dot vector element {x} at row {r} exceeds {w}-bit max"
                    );
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Is row `r` enabled?
    pub fn enabled(&self, r: usize) -> bool {
        match &self.mask {
            None => true,
            Some(m) => (m[r / 64] >> (r % 64)) & 1 == 1,
        }
    }

    /// Materialized lane mask: the query mask intersected with the
    /// `rows`-row validity mask (partial last lane zeroed).
    pub fn lanes(&self, rows: usize) -> Vec<u64> {
        let n = rows.div_ceil(64);
        let mut out = vec![u64::MAX; n];
        if rows % 64 != 0 {
            out[n - 1] = (1u64 << (rows % 64)) - 1;
        }
        if let Some(m) = &self.mask {
            for (o, &mm) in out.iter_mut().zip(m) {
                *o &= mm;
            }
        }
        out
    }
}

/// Circular transition count of the `w`-bit value `v`: unequal
/// adjacent pairs in `b_0 b_1 … b_{w-1} b_0` — the per-row toggle term
/// of the query cost closed form (see module docs).
pub fn circular_transitions(v: u32, w: usize) -> u64 {
    let m = bits::mask(w);
    let rot = ((v << 1) | (v >> (w - 1))) & m;
    u64::from(((v ^ rot) & m).count_ones())
}

/// Scalar reference executor: one decoded `w`-bit word per row (from a
/// non-counting peek path), reduced on the host with the same value
/// semantics and the same closed-form accounting as [`plane_reduce`].
pub fn scalar_reduce(spec: &QuerySpec, values: &[u32], w: usize) -> Result<(u64, BatchReport)> {
    spec.validate(values.len(), w)?;
    let mut value = spec.red.identity(w);
    let mut enabled = 0u64;
    let mut trans = 0u64;
    for (r, &v) in values.iter().enumerate() {
        if !spec.enabled(r) {
            continue;
        }
        enabled += 1;
        trans += circular_transitions(v, w);
        let term = match &spec.red {
            Reduction::Popcount => u64::from(v.count_ones()),
            Reduction::Sum => u64::from(v),
            Reduction::Min | Reduction::Max => u64::from(v),
            Reduction::RangeCount { lo, hi } => u64::from(*lo <= v && v <= *hi),
            Reduction::Dot { vec } => u64::from(v).wrapping_mul(u64::from(vec[r])),
        };
        value = spec.red.combine(value, term);
    }
    Ok((value, pass_report(&spec.red, w, enabled, trans)))
}

/// Plane-wise executor on a [`BitPlaneArray`] segment: values and
/// accounting straight from the planes, no per-row decode. Read-only —
/// the array state and its lifetime toggle counter are untouched (a
/// rotate-read pass restores every cell; the pass's activity is
/// reported in the returned [`BatchReport`], not accumulated).
pub fn plane_reduce(
    arr: &BitPlaneArray,
    seg: usize,
    spec: &QuerySpec,
) -> Result<(u64, BatchReport)> {
    let widths = arr.segment_widths();
    ensure!(seg < widths.len(), "segment {seg} out of range");
    let w = widths[seg];
    spec.validate(arr.rows(), w)?;
    let enable = spec.lanes(arr.rows());
    let lanes = arr.lanes();
    let cnt = |plane: &[u64]| -> u64 {
        plane
            .iter()
            .zip(&enable)
            .map(|(&p, &e)| u64::from((p & e).count_ones()))
            .sum()
    };
    let enabled: u64 = enable.iter().map(|e| u64::from(e.count_ones())).sum();

    let value = match &spec.red {
        Reduction::Popcount => {
            (0..w).map(|t| cnt(arr.plane(seg, t))).sum()
        }
        Reduction::Sum => (0..w).fold(0u64, |acc, t| {
            acc.wrapping_add(cnt(arr.plane(seg, t)).wrapping_mul(1u64 << t))
        }),
        Reduction::Min => {
            // MSB-first candidate filtering: keep the rows that can
            // still be minimal; a bit of the result is 0 iff some
            // candidate has a 0 there.
            let mut cand = enable.clone();
            let mut val = 0u64;
            for t in (0..w).rev() {
                let plane = arr.plane(seg, t);
                let zeros: Vec<u64> =
                    cand.iter().zip(plane).map(|(&c, &p)| c & !p).collect();
                if zeros.iter().any(|&z| z != 0) {
                    cand = zeros;
                } else {
                    val |= 1u64 << t;
                }
            }
            if enabled == 0 { u64::from(bits::mask(w)) } else { val }
        }
        Reduction::Max => {
            let mut cand = enable.clone();
            let mut val = 0u64;
            for t in (0..w).rev() {
                let plane = arr.plane(seg, t);
                let ones: Vec<u64> =
                    cand.iter().zip(plane).map(|(&c, &p)| c & p).collect();
                if ones.iter().any(|&o| o != 0) {
                    cand = ones;
                    val |= 1u64 << t;
                }
            }
            val
        }
        Reduction::RangeCount { lo, hi } => {
            let le = |bound: u32| -> u64 {
                // Bit-serial threshold compare, MSB first: `lt` holds
                // rows already decided `< bound`, `eq` the
                // equal-so-far rows.
                let mut lt = vec![0u64; lanes];
                let mut eq = enable.clone();
                for t in (0..w).rev() {
                    let plane = arr.plane(seg, t);
                    if (bound >> t) & 1 == 1 {
                        for ((lt_l, eq_l), &p) in
                            lt.iter_mut().zip(eq.iter_mut()).zip(plane)
                        {
                            *lt_l |= *eq_l & !p;
                            *eq_l &= p;
                        }
                    } else {
                        for (eq_l, &p) in eq.iter_mut().zip(plane) {
                            *eq_l &= !p;
                        }
                    }
                }
                lt.iter()
                    .chain(eq.iter())
                    .map(|&x| u64::from(x.count_ones()))
                    .sum()
            };
            le(*hi) - if *lo == 0 { 0 } else { le(*lo - 1) }
        }
        Reduction::Dot { vec } => {
            // Transpose the broadcast vector into planes one 64-row
            // block at a time, then cross the plane pairs:
            // Σ_{t,u} 2^(t+u) · cnt(V_t ∧ X_u ∧ enable)  (mod 2^64).
            let mut val = 0u64;
            let mut block = [0u64; 64];
            for l in 0..lanes {
                for (j, b) in block.iter_mut().enumerate() {
                    let r = 64 * l + j;
                    *b = if r < vec.len() { u64::from(vec[r]) } else { 0 };
                }
                bits::transpose64(&mut block);
                for t in 0..w {
                    let v_lane = arr.plane(seg, t)[l] & enable[l];
                    if v_lane == 0 {
                        continue;
                    }
                    for (u, &x_lane) in block.iter().enumerate().take(w) {
                        let c = u64::from((v_lane & x_lane).count_ones());
                        val = val
                            .wrapping_add(c.wrapping_mul(1u64.wrapping_shl((t + u) as u32)));
                    }
                }
            }
            val
        }
    };

    // Toggle closed form from plane popcounts: circular transitions
    // summed over enabled rows (see module docs).
    let mut trans = 0u64;
    for j in 0..w {
        let a = arr.plane(seg, j);
        let b = arr.plane(seg, (j + 1) % w);
        trans += a
            .iter()
            .zip(b)
            .zip(&enable)
            .map(|((&x, &y), &e)| u64::from(((x ^ y) & e).count_ones()))
            .sum::<u64>();
    }
    Ok((value, pass_report(&spec.red, w, enabled, trans)))
}

/// The rotate-read pass accounting shared by both executors.
fn pass_report(red: &Reduction, w: usize, enabled: u64, trans: u64) -> BatchReport {
    BatchReport {
        cycles: w as u64,
        rows_active: enabled,
        cell_toggles: 2 * w as u64 * trans,
        alu_evals: red.streams() * w as u64 * enabled,
    }
}

/// Per-active-bank modeled cost, identical to the update path's
/// accounting (`BankSet::apply` / `BitPlaneBackend::apply`): one
/// `batch_op(rows_per_bank, q)` per bank containing an enabled row,
/// energy summed, latency maxed. Returns `(banks_active, cost)`.
pub fn banked_cost(
    model: &FastModel,
    spec: &QuerySpec,
    rows: usize,
    rows_per_bank: usize,
    q: usize,
) -> (usize, Cost) {
    let banks = rows.div_ceil(rows_per_bank);
    let mut banks_active = 0usize;
    let mut cost = Cost::default();
    for b in 0..banks {
        let lo = b * rows_per_bank;
        let hi = rows.min(lo + rows_per_bank);
        if (lo..hi).any(|r| spec.enabled(r)) {
            banks_active += 1;
            let c = model.batch_op(rows_per_bank, q);
            cost.energy_fj += c.energy_fj;
            cost.latency_ns = cost.latency_ns.max(c.latency_ns);
        }
    }
    (banks_active, cost)
}

/// What one backend (or one shard) answers for a query.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryOutcome {
    /// The reduction's value (see [`Reduction`] for conventions).
    pub value: u64,
    /// Rotate-read pass accounting (cost closed form, module docs).
    pub report: BatchReport,
    /// Banks that held at least one enabled row.
    pub banks_active: usize,
    /// Modeled cost (energy summed over banks, latency maxed).
    pub cost: Cost,
}

// ---------------------------------------------------------------------------
// Deterministic wire helpers: seeded broadcast vectors and row masks,
// shared by `fast query`, the serve `QRY` verb and `fast client` so
// every side can regenerate the same operands from compact tokens.
// ---------------------------------------------------------------------------

/// Seeded broadcast vector for `dot`: one `q`-bit element per row.
pub fn broadcast_vec(seed: u64, rows: usize, q: usize) -> Vec<u32> {
    let mut rng = Rng::new(seed ^ 0xD07_B04D);
    (0..rows).map(|_| rng.below(1u64 << q) as u32).collect()
}

/// Seeded row mask: each row enabled with probability `pct`/100.
pub fn seeded_mask(seed: u64, pct: u32, rows: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed ^ 0x3A5_CAFE);
    let mut mask = vec![0u64; rows.div_ceil(64)];
    for r in 0..rows {
        if rng.below(100) < u64::from(pct.min(100)) {
            mask[r / 64] |= 1u64 << (r % 64);
        }
    }
    mask
}

/// Parse the token grammar shared by `QRY` lines and `fast query
/// --red`:
///
/// ```text
/// popcount | sum | min | max | range <lo> <hi> | dot <seed>
///     [mask <seed> <pct>]
/// ```
///
/// `rows`/`q` size the seeded dot vector and mask.
pub fn parse_spec(tokens: &[&str], rows: usize, q: usize) -> Result<QuerySpec> {
    let int = |tok: &str, what: &str| -> Result<u64> {
        tok.parse::<u64>()
            .map_err(|_| anyhow!("{what} expects an integer, got {tok:?}"))
    };
    let mut it = tokens.iter();
    let head = it
        .next()
        .ok_or_else(|| anyhow!("empty query (try: popcount | sum | min | max | range <lo> <hi> | dot <seed>)"))?;
    let red = match head.to_ascii_lowercase().as_str() {
        "popcount" => Reduction::Popcount,
        "sum" => Reduction::Sum,
        "min" => Reduction::Min,
        "max" => Reduction::Max,
        "range" => {
            let lo = int(it.next().ok_or_else(|| anyhow!("range needs <lo> <hi>"))?, "range lo")?;
            let hi = int(it.next().ok_or_else(|| anyhow!("range needs <lo> <hi>"))?, "range hi")?;
            ensure!(lo <= u64::from(u32::MAX) && hi <= u64::from(u32::MAX), "range bound exceeds u32");
            Reduction::RangeCount { lo: lo as u32, hi: hi as u32 }
        }
        "dot" => {
            let seed = int(it.next().ok_or_else(|| anyhow!("dot needs <seed>"))?, "dot seed")?;
            Reduction::Dot { vec: broadcast_vec(seed, rows, q) }
        }
        other => bail!("unknown reduction {other:?} (try: popcount | sum | min | max | range <lo> <hi> | dot <seed>)"),
    };
    let mask = match it.next() {
        None => None,
        Some(tok) if tok.eq_ignore_ascii_case("mask") => {
            let seed = int(it.next().ok_or_else(|| anyhow!("mask needs <seed> <pct>"))?, "mask seed")?;
            let pct = int(it.next().ok_or_else(|| anyhow!("mask needs <seed> <pct>"))?, "mask pct")?;
            ensure!(pct <= 100, "mask pct {pct} exceeds 100");
            Some(seeded_mask(seed, pct as u32, rows))
        }
        Some(other) => bail!("unexpected query token {other:?} (only a trailing `mask <seed> <pct>` is allowed)"),
    };
    match it.next() {
        None => {}
        Some(t) => bail!("trailing query token {t:?}"),
    }
    let spec = QuerySpec { red, mask };
    spec.validate(rows, q)?;
    Ok(spec)
}

/// Slice a logical-row spec into one local spec per shard, following
/// the engine's route (`shard = row & (shards-1)`, `local = row >>
/// shard_bits`). Partial results recombine with [`Reduction::combine`].
pub fn shard_specs(spec: &QuerySpec, rows: usize, shards: usize) -> Result<Vec<QuerySpec>> {
    ensure!(shards >= 1 && shards.is_power_of_two(), "shards must be a power of two");
    ensure!(rows % shards == 0, "rows {rows} not divisible by shards {shards}");
    if shards == 1 {
        return Ok(vec![spec.clone()]);
    }
    let bits = shards.trailing_zeros() as usize;
    let local_rows = rows >> bits;
    let lanes = local_rows.div_ceil(64);
    let mut masks = vec![vec![0u64; lanes]; shards];
    let mut vecs: Vec<Vec<u32>> = match &spec.red {
        Reduction::Dot { .. } => vec![vec![0u32; local_rows]; shards],
        _ => Vec::new(),
    };
    for r in 0..rows {
        let shard = r & (shards - 1);
        let local = r >> bits;
        if spec.enabled(r) {
            masks[shard][local / 64] |= 1u64 << (local % 64);
        }
        if let Reduction::Dot { vec } = &spec.red {
            vecs[shard][local] = vec[r];
        }
    }
    Ok((0..shards)
        .map(|s| QuerySpec {
            red: match &spec.red {
                Reduction::Dot { .. } => Reduction::Dot { vec: std::mem::take(&mut vecs[s]) },
                other => other.clone(),
            },
            mask: Some(std::mem::take(&mut masks[s])),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::check;

    fn all_reductions(g: &mut crate::util::quickprop::Gen, rows: usize, q: usize) -> Reduction {
        match g.usize_in(0, 5) {
            0 => Reduction::Popcount,
            1 => Reduction::Sum,
            2 => Reduction::Min,
            3 => Reduction::Max,
            4 => {
                let a = g.u32_any() & bits::mask(q);
                let b = g.u32_any() & bits::mask(q);
                Reduction::RangeCount { lo: a.min(b), hi: a.max(b) }
            }
            _ => Reduction::Dot { vec: broadcast_vec(g.u64_any(), rows, q) },
        }
    }

    /// Independent oracle, written as plainly as possible.
    fn oracle(spec: &QuerySpec, values: &[u32], w: usize) -> u64 {
        let enabled: Vec<(usize, u32)> = values
            .iter()
            .copied()
            .enumerate()
            .filter(|&(r, _)| spec.enabled(r))
            .collect();
        match &spec.red {
            Reduction::Popcount => enabled.iter().map(|&(_, v)| u64::from(v.count_ones())).sum(),
            Reduction::Sum => enabled
                .iter()
                .fold(0u64, |a, &(_, v)| a.wrapping_add(u64::from(v))),
            Reduction::Min => enabled
                .iter()
                .map(|&(_, v)| u64::from(v))
                .min()
                .unwrap_or(u64::from(bits::mask(w))),
            Reduction::Max => enabled.iter().map(|&(_, v)| u64::from(v)).max().unwrap_or(0),
            Reduction::RangeCount { lo, hi } => enabled
                .iter()
                .filter(|&&(_, v)| *lo <= v && v <= *hi)
                .count() as u64,
            Reduction::Dot { vec } => enabled.iter().fold(0u64, |a, &(r, v)| {
                a.wrapping_add(u64::from(v).wrapping_mul(u64::from(vec[r])))
            }),
        }
    }

    /// PROPERTY: scalar and plane-wise executors agree with the plain
    /// oracle on values and with each other on full reports, for
    /// random rows/widths/masks — and the plane pass is read-only.
    #[test]
    fn prop_scalar_and_plane_agree_with_oracle() {
        check("query executors vs oracle", 40, |g| {
            let rows = g.usize_in(1, 170);
            let q = *g.choose(&[1usize, 4, 8, 16, 32]);
            let values: Vec<u32> =
                (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
            let spec = if g.bool() {
                QuerySpec::all(all_reductions(g, rows, q))
            } else {
                QuerySpec::masked(
                    all_reductions(g, rows, q),
                    seeded_mask(g.u64_any(), g.u32_below(101), rows),
                )
            };
            let mut arr = BitPlaneArray::new(rows, &[q]);
            arr.fill_from(|r, _| values[r]);
            let toggles_before = arr.toggles();
            let (sv, sr) = scalar_reduce(&spec, &values, q).unwrap();
            let (pv, pr) = plane_reduce(&arr, 0, &spec).unwrap();
            let mut ok = sv == oracle(&spec, &values, q);
            ok &= pv == sv && pr == sr;
            ok &= arr.toggles() == toggles_before;
            ok &= (0..rows).all(|r| arr.read_word(r, 0) == values[r]);
            ok
        });
    }

    /// PROPERTY: shard-sliced specs recombine to the unsharded result
    /// for every shard count the engine supports.
    #[test]
    fn prop_shard_slicing_recombines() {
        check("shard slicing", 30, |g| {
            let shards = *g.choose(&[1usize, 2, 4, 8]);
            let rows = shards * g.usize_in(1, 3) * 32;
            let q = *g.choose(&[4usize, 8, 16]);
            let values: Vec<u32> =
                (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
            let spec = QuerySpec::masked(
                all_reductions(g, rows, q),
                seeded_mask(g.u64_any(), g.u32_below(101), rows),
            );
            let (want, wr) = scalar_reduce(&spec, &values, q).unwrap();
            let bits_n = shards.trailing_zeros() as usize;
            let locals = shard_specs(&spec, rows, shards).unwrap();
            let mut got = spec.red.identity(q);
            let mut report = BatchReport::default();
            for (s, local) in locals.iter().enumerate() {
                let lv: Vec<u32> = (0..rows / shards)
                    .map(|l| values[(l << bits_n) | s])
                    .collect();
                let (v, r) = scalar_reduce(local, &lv, q).unwrap();
                got = spec.red.combine(got, v);
                report.cycles = report.cycles.max(r.cycles);
                report.rows_active += r.rows_active;
                report.cell_toggles += r.cell_toggles;
                report.alu_evals += r.alu_evals;
            }
            got == want
                && report.rows_active == wr.rows_active
                && report.cell_toggles == wr.cell_toggles
                && report.alu_evals == wr.alu_evals
        });
    }

    #[test]
    fn parse_grammar_round_trips() {
        let rows = 128;
        let q = 8;
        let s = parse_spec(&["popcount"], rows, q).unwrap();
        assert_eq!(s.red, Reduction::Popcount);
        assert!(s.mask.is_none());
        let s = parse_spec(&["RANGE", "3", "9"], rows, q).unwrap();
        assert_eq!(s.red, Reduction::RangeCount { lo: 3, hi: 9 });
        let s = parse_spec(&["dot", "42", "mask", "7", "50"], rows, q).unwrap();
        assert_eq!(s.red, Reduction::Dot { vec: broadcast_vec(42, rows, q) });
        assert_eq!(s.mask, Some(seeded_mask(7, 50, rows)));
        for bad in [
            vec![],
            vec!["median"],
            vec!["range", "9"],
            vec!["range", "9", "3"],
            vec!["range", "3", "9999"],
            vec!["dot"],
            vec!["sum", "mask", "7"],
            vec!["sum", "extra"],
            vec!["sum", "mask", "7", "50", "extra"],
        ] {
            assert!(parse_spec(&bad, rows, q).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn empty_mask_conventions() {
        let rows = 70;
        let q = 8;
        let values = vec![0xABu32 & bits::mask(q); rows];
        let mask = vec![0u64; rows.div_ceil(64)];
        for red in [Reduction::Min, Reduction::Max, Reduction::Sum, Reduction::Popcount] {
            let spec = QuerySpec::masked(red, mask.clone());
            let (v, r) = scalar_reduce(&spec, &values, q).unwrap();
            let mut arr = BitPlaneArray::new(rows, &[q]);
            arr.fill_from(|r2, _| values[r2]);
            let (pv, pr) = plane_reduce(&arr, 0, &spec).unwrap();
            assert_eq!(v, pv);
            assert_eq!(r, pr);
            assert_eq!(r.rows_active, 0);
            assert_eq!(r.cell_toggles, 0);
            match spec.red {
                Reduction::Min => assert_eq!(v, u64::from(bits::mask(q))),
                _ => assert_eq!(v, 0),
            }
        }
    }

    #[test]
    fn banked_cost_matches_update_accounting() {
        let model = FastModel::default();
        let spec = QuerySpec::all(Reduction::Sum);
        let (banks, cost) = banked_cost(&model, &spec, 256, 128, 16);
        assert_eq!(banks, 2);
        let one = model.batch_op(128, 16);
        assert!((cost.energy_fj - 2.0 * one.energy_fj).abs() < 1e-9);
        assert!((cost.latency_ns - one.latency_ns).abs() < 1e-12);
        // A mask confined to bank 0 gates bank 1.
        let mut m = vec![0u64; 4];
        m[0] = 1;
        let (banks, cost) = banked_cost(&model, &QuerySpec::masked(Reduction::Sum, m), 256, 128, 16);
        assert_eq!(banks, 1);
        assert!((cost.energy_fj - one.energy_fj).abs() < 1e-9);
    }
}
