//! Durability subsystem: segmented write-ahead log, full-state
//! snapshots, and crash recovery for the ticketed update engine.
//!
//! A `fast serve` process used to lose every committed batch when it
//! died; no table/trainer workload could trust it. This layer turns
//! the engine's existing commit machinery into persistence:
//!
//! - [`wal`] — the binary, CRC32-framed, size-segmented log. One
//!   appender per shard, driven through the engine's
//!   [`CommitListener`](crate::coordinator::CommitListener) hook so a
//!   group-commit *seal* is exactly one buffered frame write plus at
//!   most one coalesced fsync (per the [`FsyncPolicy`]). The per-shard
//!   `commit_seq` from the ticket machinery is the record's identity;
//!   a shard-local LSN orders conventional-port writes between seals.
//! - [`segment`] — on-disk layout: per-shard directories of segments
//!   named by first LSN, plus the `wal.json` shape manifest that stops
//!   two differently-shaped engines from sharing a directory.
//! - [`snapshot`] — atomic (temp-file + rename) full-state snapshots
//!   carrying the row state, every shard's `(commit_seq, lsn)`
//!   watermark and a verified digest; the anchor that lets compaction
//!   retire covered segments.
//! - [`recover`] — startup recovery: newest valid snapshot, then each
//!   shard's WAL tail (deduped by commit_seq/LSN, torn tails truncated
//!   at the first bad frame), digest-verified; plus offline
//!   [`recover::compact`] and the WAL→`fast-trace-v1`
//!   [`recover::export_trace`] interop that lets
//!   `fast trace replay --digest-only` independently audit any
//!   recovered state.
//! - [`cursor`] — read-only live tailing of a shard's segments for
//!   WAL shipping ([`crate::replication`]): yields each durable frame
//!   exactly once from a chosen LSN, distinguishing an in-flight
//!   append (retry) from corruption (hard error) and reporting
//!   segment-rotation boundaries for digest exchange.
//!
//! Wiring: set [`DurabilityConfig`] on
//! [`EngineConfig`](crate::coordinator::EngineConfig) (CLI:
//! `fast serve --wal-dir DIR [--fsync always|interval|off]`) and the
//! engine recovers before accepting work; `fast wal
//! inspect|verify|compact|export` operate on the directory offline.
//!
//! Multi-tenant serves compose with this layer unchanged: a
//! [`crate::tenant::TenantRegistry`] rooted at `--wal-dir` keeps its
//! `tenants.json` manifest in the root and gives **each tenant** a
//! standard durable engine directory at `<root>/tenants/<name>/` —
//! its own per-shard segments, snapshots (the per-tenant snapshot
//! watermark), single-writer lock and torn-tail repair — so every
//! offline `fast wal` verb works on a tenant by pointing `--dir` at
//! its subdirectory, and recovery of one tenant never reads another's
//! log.

pub mod cursor;
pub mod recover;
pub mod segment;
pub mod snapshot;
pub mod wal;

use std::path::PathBuf;
use std::time::Duration;

pub use cursor::{CursorEvent, WalCursor};
pub use recover::{
    compact, export_trace, recover, recover_force, recover_or_init, recover_repair,
    CompactReport, RecoverReport, TornNote,
};
pub use segment::{DirLock, Manifest};
pub use snapshot::{ShardMark, Snapshot};
pub use wal::{
    coalesce_rows, has_segment_stats, load_segment_stats, FsyncPolicy, SegmentReader,
    SegmentWriteStats, ShardWal, WalPayload, WalRecord,
};

/// Default segment-rotation threshold (bytes).
pub const DEFAULT_SEGMENT_BYTES: u64 = 4 * 1024 * 1024;

/// Default fsync coalescing interval for [`FsyncPolicy::Interval`].
pub const DEFAULT_FSYNC_INTERVAL: Duration = Duration::from_micros(2000);

/// The durability knobs carried by
/// [`EngineConfig`](crate::coordinator::EngineConfig).
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// WAL directory (created on first use; its `wal.json` manifest
    /// pins the engine shape thereafter).
    pub dir: PathBuf,
    /// When appended records hit the disk (CLI `--fsync`).
    pub fsync: FsyncPolicy,
    /// Segment rotation threshold in bytes (CLI `--wal-segment-bytes`).
    pub segment_bytes: u64,
}

impl DurabilityConfig {
    /// Sensible defaults: interval fsync (2 ms coalescing window),
    /// 4 MiB segments.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DurabilityConfig {
            dir: dir.into(),
            fsync: FsyncPolicy::Interval(DEFAULT_FSYNC_INTERVAL),
            segment_bytes: DEFAULT_SEGMENT_BYTES,
        }
    }
}
