//! The segmented binary write-ahead log: frame codec, torn-tail-aware
//! segment reader, and the per-shard appender that rides the engine's
//! group-commit seals.
//!
//! ## Frame format (`fast-wal-v1`)
//!
//! ```text
//! frame   := len:u32 | crc:u32 | payload        (len = payload bytes,
//!                                                crc = CRC32(payload))
//! payload := rtype:u8 | shard:u32 | lsn:u64 | commit_seq:u64
//!          | seal_reason:u8 | kind:u8 | nops:u32 | nops×(row:u32, val:u32)
//! ```
//!
//! All integers little-endian. `rtype` 1 = sealed-batch commit (`ops`
//! are the batch's non-identity `(local_row, operand)` pairs after
//! coalescing), `rtype` 2 = conventional-port absolute write (`nops`
//! = 1, `commit_seq` = the shard's last committed seq at log time —
//! writes do not mint commit seqs). `lsn` is the shard's own log
//! sequence number, strictly increasing across every record the shard
//! ever logs; it is the recovery watermark (commit_seq alone cannot
//! order writes between two batch commits).
//!
//! ## Group-commit alignment and cross-seal coalescing
//!
//! One engine seal = one [`ShardWal::append_batch`] = one frame encoded
//! into a reusable buffer and at most one fsync (per the
//! [`FsyncPolicy`]) — durability amortizes exactly like the group
//! commit it rides; there is never a syscall per request.
//!
//! Under the `interval` and `off` policies the appender goes further
//! and coalesces *across* seals: frames accumulate in a staging buffer
//! and ship in ONE `write_all` when the buffer hits
//! [`COALESCE_MAX_BYTES`] / [`COALESCE_MAX_FRAMES`], when the worker
//! goes quiescent (its queue drained), at every barrier / rotation /
//! fsync, and on drop. The bytes that reach the file are identical to
//! the unstaged stream — same frames, same order — so recovery is
//! unchanged; only the syscall count drops. The `always` policy
//! bypasses staging entirely: its contract is "frame on disk before
//! the ticket resolves", which leaves nothing to coalesce with.
//!
//! ## Torn tails
//!
//! [`SegmentReader`] stops at the first bad frame (short header, bogus
//! length, CRC mismatch, undecodable payload) and reports the byte
//! offset of the good prefix; recovery truncates there (`repair`) so
//! the log is always a prefix of what was appended — never a
//! reordering, never a gap.

use std::fs::{File, OpenOptions};
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context};

use crate::coordinator::batcher::SealReason;
use crate::coordinator::engine::CommitListener;
use crate::coordinator::request::{BatchKind, Commit};
use crate::metrics::{Counters, ShardCounters};
use crate::Result;

use super::segment::{
    self, encode_segment_header, read_segment_header, SEGMENT_HEADER_LEN,
};

/// Upper bound on one frame's payload (sanity cap so a corrupt length
/// field can never trigger a giant allocation).
pub const MAX_PAYLOAD: u32 = 1 << 26; // 64 MiB

/// Fixed payload bytes before the ops array.
const PAYLOAD_FIXED: usize = 1 + 4 + 8 + 8 + 1 + 1 + 4;

/// Staged bytes that force a coalesced `write_all` (cross-seal
/// coalescing under the `interval` / `off` fsync policies).
pub const COALESCE_MAX_BYTES: usize = 256 * 1024;
/// Staged frames that force a coalesced `write_all`.
pub const COALESCE_MAX_FRAMES: u64 = 64;

/// When to fsync the shard's segment file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every appended record: a resolved ticket implies
    /// the commit is on disk. Safest, slowest.
    Always,
    /// fsync at most once per interval (checked at append time) plus
    /// at every barrier (drain / snapshot / shutdown). A crash can
    /// lose up to one interval of *acknowledged* commits; recovery is
    /// still prefix-consistent.
    Interval(Duration),
    /// Never fsync explicitly; the OS flushes on its own schedule.
    /// Frames reach the kernel at the coalescing window's edge (caps,
    /// quiescence, barriers) rather than per record, so a process kill
    /// can additionally lose the staged window — at most
    /// [`COALESCE_MAX_FRAMES`] frames / [`COALESCE_MAX_BYTES`] bytes
    /// of the active burst. Recovery stays prefix-consistent either
    /// way. Does not survive power loss.
    Off,
}

impl FsyncPolicy {
    /// Parse the CLI spelling: `always` | `interval` | `off`.
    pub fn parse(s: &str, interval: Duration) -> Result<FsyncPolicy> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "interval" => Ok(FsyncPolicy::Interval(interval)),
            "off" => Ok(FsyncPolicy::Off),
            other => bail!("unknown fsync policy {other:?} (always|interval|off)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Interval(_) => "interval",
            FsyncPolicy::Off => "off",
        }
    }
}

/// What one WAL record carries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalPayload {
    /// A sealed batch the backend applied: the commit's seal reason,
    /// batch kind, and the non-identity `(local_row, operand)` pairs.
    Batch {
        seal_reason: SealReason,
        kind: BatchKind,
        ops: Vec<(u32, u32)>,
    },
    /// A conventional-port absolute write.
    Write { row: u32, value: u32 },
}

/// One decoded WAL record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalRecord {
    pub shard: u32,
    /// Shard-local log sequence number (strictly increasing).
    pub lsn: u64,
    /// Batch records: the minted commit seq. Write records: the
    /// shard's last committed seq when the write was logged.
    pub commit_seq: u64,
    pub payload: WalPayload,
}

fn seal_to_u8(r: SealReason) -> u8 {
    match r {
        SealReason::Full => 0,
        SealReason::KindChange => 1,
        SealReason::Deadline => 2,
        SealReason::Forced => 3,
    }
}

fn seal_from_u8(b: u8) -> Result<SealReason> {
    Ok(match b {
        0 => SealReason::Full,
        1 => SealReason::KindChange,
        2 => SealReason::Deadline,
        3 => SealReason::Forced,
        other => bail!("bad seal reason byte {other}"),
    })
}

fn kind_to_u8(k: BatchKind) -> u8 {
    match k {
        BatchKind::Add => 0,
        BatchKind::And => 1,
        BatchKind::Or => 2,
        BatchKind::Xor => 3,
    }
}

fn kind_from_u8(b: u8) -> Result<BatchKind> {
    Ok(match b {
        0 => BatchKind::Add,
        1 => BatchKind::And,
        2 => BatchKind::Or,
        3 => BatchKind::Xor,
        other => bail!("bad batch kind byte {other}"),
    })
}

/// Encode one complete frame (len + crc + payload) into `buf` from
/// streamed ops — the shared encoder behind [`WalRecord::encode_into`]
/// and the appender's allocation-free hot path (the ops iterate
/// straight out of the batch's operand vector; nothing is collected).
/// Returns the frame length in bytes.
#[allow(clippy::too_many_arguments)]
fn encode_frame(
    buf: &mut Vec<u8>,
    shard: u32,
    lsn: u64,
    commit_seq: u64,
    rtype: u8,
    seal: u8,
    kind: u8,
    nops: usize,
    ops: impl Iterator<Item = (u32, u32)>,
) -> usize {
    let start = buf.len();
    let len = PAYLOAD_FIXED + nops * 8;
    buf.reserve(8 + len);
    buf.extend_from_slice(&(len as u32).to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]); // crc backfilled below
    let payload_at = buf.len();
    buf.push(rtype);
    buf.extend_from_slice(&shard.to_le_bytes());
    buf.extend_from_slice(&lsn.to_le_bytes());
    buf.extend_from_slice(&commit_seq.to_le_bytes());
    buf.push(seal);
    buf.push(kind);
    buf.extend_from_slice(&(nops as u32).to_le_bytes());
    for (row, val) in ops {
        buf.extend_from_slice(&row.to_le_bytes());
        buf.extend_from_slice(&val.to_le_bytes());
    }
    debug_assert_eq!(buf.len() - payload_at, len, "nops disagrees with the ops iterator");
    let crc = crate::util::crc32::crc32(&buf[payload_at..]);
    buf[payload_at - 4..payload_at].copy_from_slice(&crc.to_le_bytes());
    buf.len() - start
}

impl WalRecord {
    /// Append this record's complete frame (len + crc + payload) to
    /// `buf`. Returns the frame length in bytes.
    pub fn encode_into(&self, buf: &mut Vec<u8>) -> usize {
        match &self.payload {
            WalPayload::Batch { seal_reason, kind, ops } => encode_frame(
                buf,
                self.shard,
                self.lsn,
                self.commit_seq,
                1,
                seal_to_u8(*seal_reason),
                kind_to_u8(*kind),
                ops.len(),
                ops.iter().copied(),
            ),
            WalPayload::Write { row, value } => encode_frame(
                buf,
                self.shard,
                self.lsn,
                self.commit_seq,
                2,
                0,
                0,
                1,
                std::iter::once((*row, *value)),
            ),
        }
    }

    /// Decode one frame payload (after the CRC already verified).
    pub fn decode(payload: &[u8]) -> Result<WalRecord> {
        ensure!(payload.len() >= PAYLOAD_FIXED, "payload too short ({} bytes)", payload.len());
        let u32_at = |at: usize| u32::from_le_bytes(payload[at..at + 4].try_into().expect("4"));
        let u64_at = |at: usize| u64::from_le_bytes(payload[at..at + 8].try_into().expect("8"));
        let rtype = payload[0];
        let shard = u32_at(1);
        let lsn = u64_at(5);
        let commit_seq = u64_at(13);
        let seal = payload[21];
        let kind = payload[22];
        let nops = u32_at(23) as usize;
        ensure!(
            payload.len() == PAYLOAD_FIXED + nops * 8,
            "payload length {} != header-implied {}",
            payload.len(),
            PAYLOAD_FIXED + nops * 8
        );
        let pair_at =
            |i: usize| (u32_at(PAYLOAD_FIXED + i * 8), u32_at(PAYLOAD_FIXED + i * 8 + 4));
        let record = match rtype {
            1 => {
                let ops = (0..nops).map(pair_at).collect();
                WalRecord {
                    shard,
                    lsn,
                    commit_seq,
                    payload: WalPayload::Batch {
                        seal_reason: seal_from_u8(seal)?,
                        kind: kind_from_u8(kind)?,
                        ops,
                    },
                }
            }
            2 => {
                ensure!(nops == 1, "write record must carry exactly one op, got {nops}");
                let (row, value) = pair_at(0);
                WalRecord { shard, lsn, commit_seq, payload: WalPayload::Write { row, value } }
            }
            other => bail!("bad record type byte {other}"),
        };
        Ok(record)
    }
}

// ---------------------------------------------------------------------------
// Segment reader
// ---------------------------------------------------------------------------

/// Why a segment scan stopped before end-of-file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TornTail {
    /// Byte offset of the first bad frame — the length of the good
    /// prefix, the offset `repair` truncates at.
    pub offset: u64,
    pub reason: String,
}

/// Sequential reader over one segment file. Stops (without erroring)
/// at the first bad frame and reports it via [`Self::torn`]; a clean
/// EOF leaves `torn` unset.
pub struct SegmentReader {
    r: BufReader<File>,
    path: PathBuf,
    shard: u32,
    /// Bytes of validated frames consumed so far (header included).
    offset: u64,
    torn: Option<TornTail>,
    done: bool,
}

impl SegmentReader {
    /// Open a segment and validate its header. A header that is
    /// missing, short, or foreign is an `Err` — the caller decides
    /// whether that means "torn at byte 0" (repair removes the file)
    /// or corruption.
    pub fn open(path: &Path, expect_shard: usize) -> Result<SegmentReader> {
        let file =
            File::open(path).with_context(|| format!("opening segment {}", path.display()))?;
        let mut r = BufReader::new(file);
        let shard = read_segment_header(&mut r, path)?;
        ensure!(
            shard as usize == expect_shard,
            "{}: segment claims shard {shard}, found in shard {expect_shard}'s directory",
            path.display()
        );
        Ok(SegmentReader {
            r,
            path: path.to_path_buf(),
            shard,
            offset: SEGMENT_HEADER_LEN,
            torn: None,
            done: false,
        })
    }

    /// The first bad frame, if the scan hit one.
    pub fn torn(&self) -> Option<&TornTail> {
        self.torn.as_ref()
    }

    /// Bytes of good frames consumed (the truncation point on repair).
    pub fn offset(&self) -> u64 {
        self.offset
    }

    fn mark_torn(&mut self, reason: String) {
        self.torn = Some(TornTail { offset: self.offset, reason });
        self.done = true;
    }

    /// Next record, or `None` at clean EOF / first bad frame.
    pub fn next_record(&mut self) -> Option<WalRecord> {
        if self.done {
            return None;
        }
        let mut head = [0u8; 8];
        match read_full(&mut self.r, &mut head) {
            Ok(0) => {
                self.done = true;
                return None;
            }
            Ok(8) => {}
            Ok(n) => {
                self.mark_torn(format!("frame header truncated ({n} of 8 bytes)"));
                return None;
            }
            Err(e) => {
                self.mark_torn(format!("reading frame header: {e}"));
                return None;
            }
        }
        let len = u32::from_le_bytes(head[..4].try_into().expect("4"));
        let crc = u32::from_le_bytes(head[4..].try_into().expect("4"));
        if len < PAYLOAD_FIXED as u32 || len > MAX_PAYLOAD {
            self.mark_torn(format!("implausible frame length {len}"));
            return None;
        }
        let mut payload = vec![0u8; len as usize];
        match read_full(&mut self.r, &mut payload) {
            Ok(n) if n == len as usize => {}
            Ok(n) => {
                self.mark_torn(format!("frame payload truncated ({n} of {len} bytes)"));
                return None;
            }
            Err(e) => {
                self.mark_torn(format!("reading frame payload: {e}"));
                return None;
            }
        }
        if crate::util::crc32::crc32(&payload) != crc {
            self.mark_torn("frame CRC mismatch".to_string());
            return None;
        }
        match WalRecord::decode(&payload) {
            Ok(rec) => {
                if rec.shard != self.shard {
                    self.mark_torn(format!(
                        "record claims shard {}, segment {} belongs to shard {}",
                        rec.shard,
                        self.path.display(),
                        self.shard
                    ));
                    return None;
                }
                self.offset += 8 + len as u64;
                Some(rec)
            }
            Err(e) => {
                self.mark_torn(format!("undecodable payload: {e}"));
                None
            }
        }
    }
}

/// `read_exact` that reports how many bytes it got instead of erroring
/// on a short tail (torn tails are expected, not exceptional).
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

// ---------------------------------------------------------------------------
// Appender
// ---------------------------------------------------------------------------

/// Per-segment write statistics kept in the shard directory's sidecar
/// (`coalesce.json`) so `fast wal inspect` can report the coalescing
/// ratio (frames/write, bytes/write) long after the appender is gone.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SegmentWriteStats {
    /// `write_all` calls that landed in this segment.
    pub writes: u64,
    /// Frames those writes delivered.
    pub frames: u64,
    /// Bytes those writes delivered (frame bytes, header excluded).
    pub bytes: u64,
    /// Writes that carried ≥ 2 coalesced frames.
    pub coalesced_writes: u64,
    /// Frames delivered by those coalesced writes.
    pub coalesced_frames: u64,
}

/// Sidecar file name inside each shard directory. Deliberately not
/// `seg-*.wal`, so [`segment::list_segments`] (and therefore recovery)
/// never sees it.
pub const STATS_FILE: &str = "coalesce.json";

fn stats_path(root: &Path, shard: usize) -> PathBuf {
    segment::shard_dir(root, shard).join(STATS_FILE)
}

/// Whether the shard directory carries a coalesce sidecar at all —
/// distinguishes "no sidecar (pre-coalescing log)" from "sidecar with
/// no interesting segments" for `fast wal inspect`.
pub fn has_segment_stats(root: &Path, shard: usize) -> bool {
    stats_path(root, shard).is_file()
}

/// The `fast wal inspect` coalescing rows for one shard: per-segment
/// frames/write + bytes/write when the sidecar exists, or one explicit
/// `(no sidecar)` row when it does not (older WAL dirs predate the
/// sidecar — silence would read as "no coalescing happened").
pub fn coalesce_rows(root: &Path, shard: usize) -> Vec<(String, String)> {
    if !has_segment_stats(root, shard) {
        return vec![(format!("shard {shard} coalesce"), "(no sidecar)".to_string())];
    }
    let stats = load_segment_stats(root, shard).unwrap_or_default();
    let mut rows = Vec::new();
    for (first_lsn, st) in &stats {
        if st.writes == 0 {
            continue;
        }
        rows.push((
            format!("shard {shard} seg-{first_lsn:016x}"),
            format!(
                "{} writes | {:.1} frames/write | {:.0} bytes/write | \
                 {} coalesced ({} frames)",
                st.writes,
                st.frames as f64 / st.writes as f64,
                st.bytes as f64 / st.writes as f64,
                st.coalesced_writes,
                st.coalesced_frames,
            ),
        ));
    }
    rows
}

/// Load the per-segment write-stats sidecar. A missing file is an
/// empty map (older logs have none); a corrupt one is an error the
/// caller may treat as advisory — the sidecar is diagnostics, never
/// recovery input.
pub fn load_segment_stats(
    root: &Path,
    shard: usize,
) -> Result<std::collections::BTreeMap<u64, SegmentWriteStats>> {
    use crate::util::json::Json;
    let path = stats_path(root, shard);
    let mut out = std::collections::BTreeMap::new();
    if !path.is_file() {
        return Ok(out);
    }
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(text.trim())
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    ensure!(
        j.get("wal_stats").and_then(Json::as_str) == Some("fast-wal-v1"),
        "{} is not a fast-wal-v1 stats sidecar",
        path.display()
    );
    let Some(segs) = j.get("segments").and_then(Json::as_obj) else {
        bail!("{}: no segments object", path.display());
    };
    for (hex, v) in segs {
        let Ok(first_lsn) = u64::from_str_radix(hex, 16) else {
            continue;
        };
        let field = |k: &str| v.get(k).and_then(Json::as_usize).unwrap_or(0) as u64;
        out.insert(
            first_lsn,
            SegmentWriteStats {
                writes: field("writes"),
                frames: field("frames"),
                bytes: field("bytes"),
                coalesced_writes: field("coalesced_writes"),
                coalesced_frames: field("coalesced_frames"),
            },
        );
    }
    Ok(out)
}

/// The per-shard WAL appender: owned by the shard's worker thread,
/// driven through the engine's [`CommitListener`] hook so every record
/// lands *after* the backend apply and *before* any completion ticket
/// resolves. Rotation, fsync policy, cross-seal coalescing and metrics
/// are internal.
pub struct ShardWal {
    root: PathBuf,
    shard: usize,
    q: usize,
    fsync: FsyncPolicy,
    segment_bytes: u64,
    file: File,
    /// Bytes accounted to the current segment, staged frames included
    /// (staging changes when bytes hit the file, not which file).
    seg_bytes: u64,
    next_lsn: u64,
    last_sync: Instant,
    dirty: bool,
    /// Reusable frame-encode buffer (no allocation on the hot path).
    buf: Vec<u8>,
    /// Cross-seal staging buffer: encoded frames waiting for one
    /// coalesced `write_all`. Always empty under `FsyncPolicy::Always`.
    staged: Vec<u8>,
    staged_frames: u64,
    /// First LSN of the current segment (its sidecar stats key).
    seg_first_lsn: u64,
    seg_stats: SegmentWriteStats,
    stats_map: std::collections::BTreeMap<u64, SegmentWriteStats>,
    metrics: Option<Arc<ShardCounters>>,
}

impl ShardWal {
    /// Open (or create) the shard's log for appending at `next_lsn`.
    /// Recovery must already have truncated any torn tail — this
    /// appends blindly to the newest segment.
    pub fn open(
        root: &Path,
        shard: usize,
        q: usize,
        next_lsn: u64,
        fsync: FsyncPolicy,
        segment_bytes: u64,
        metrics: Option<Arc<ShardCounters>>,
    ) -> Result<ShardWal> {
        ensure!(next_lsn >= 1, "lsn space starts at 1");
        ensure!(segment_bytes >= 1024, "segment_bytes must be >= 1024");
        let sdir = segment::shard_dir(root, shard);
        std::fs::create_dir_all(&sdir)
            .with_context(|| format!("creating {}", sdir.display()))?;
        let segs = segment::list_segments(root, shard)?;
        let (file, seg_bytes, seg_first_lsn) = match segs.last() {
            Some(last) if last.bytes >= SEGMENT_HEADER_LEN => {
                let f = OpenOptions::new()
                    .append(true)
                    .open(&last.path)
                    .with_context(|| format!("opening {} for append", last.path.display()))?;
                (f, last.bytes, last.first_lsn)
            }
            _ => {
                // No segment yet (or a headerless stub recovery chose
                // not to keep): start a fresh one at next_lsn.
                if let Some(stub) = segs.last() {
                    let _ = std::fs::remove_file(&stub.path);
                }
                let (f, b) = Self::create_segment(root, shard, next_lsn)?;
                (f, b, next_lsn)
            }
        };
        // The sidecar is diagnostics; a corrupt one must never block a
        // durable start — start its stats over instead.
        let stats_map = load_segment_stats(root, shard).unwrap_or_default();
        let seg_stats = stats_map.get(&seg_first_lsn).copied().unwrap_or_default();
        Ok(ShardWal {
            root: root.to_path_buf(),
            shard,
            q,
            fsync,
            segment_bytes,
            file,
            seg_bytes,
            next_lsn,
            last_sync: Instant::now(),
            dirty: false,
            buf: Vec::with_capacity(4096),
            staged: Vec::new(),
            staged_frames: 0,
            seg_first_lsn,
            seg_stats,
            stats_map,
            metrics,
        })
    }

    fn create_segment(root: &Path, shard: usize, first_lsn: u64) -> Result<(File, u64)> {
        let path = segment::segment_path(root, shard, first_lsn);
        let mut f = OpenOptions::new()
            .create_new(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("creating segment {}", path.display()))?;
        f.write_all(&encode_segment_header(shard))?;
        Ok((f, SEGMENT_HEADER_LEN))
    }

    /// The next LSN this appender will assign.
    pub fn next_lsn(&self) -> u64 {
        self.next_lsn
    }

    /// Log one sealed batch: the commit metadata plus the batch's
    /// non-identity `(row, operand)` pairs, streamed straight from the
    /// dense operand vector into the reusable frame buffer (no
    /// intermediate allocation). One buffered frame, one `write_all`,
    /// at most one fsync — aligned with the group-commit seal this
    /// rides.
    pub fn append_batch(
        &mut self,
        commit: &Commit,
        kind: BatchKind,
        operands: &[u32],
    ) -> Result<()> {
        self.maybe_rotate()?;
        let ident = kind.identity(self.q);
        // A batch whose every coalesced operand cancelled to identity
        // still logs (zero ops) so commit_seq stays dense in the log.
        let nops = operands.iter().filter(|&&o| o != ident).count();
        self.buf.clear();
        let frame_len = encode_frame(
            &mut self.buf,
            self.shard as u32,
            self.next_lsn,
            commit.commit_seq,
            1,
            seal_to_u8(commit.seal_reason),
            kind_to_u8(kind),
            nops,
            operands
                .iter()
                .enumerate()
                .filter(|&(_, &o)| o != ident)
                .map(|(r, &o)| (r as u32, o)),
        );
        self.write_frame(frame_len as u64)
    }

    /// Log one conventional-port write. `committed_seq` is the shard's
    /// last committed seq (writes do not mint seqs; the LSN orders
    /// them between commits).
    pub fn append_write(&mut self, row: usize, value: u32, committed_seq: u64) -> Result<()> {
        self.maybe_rotate()?;
        self.buf.clear();
        let frame_len = encode_frame(
            &mut self.buf,
            self.shard as u32,
            self.next_lsn,
            committed_seq,
            2,
            0,
            0,
            1,
            std::iter::once((row as u32, value)),
        );
        self.write_frame(frame_len as u64)
    }

    /// Ship (or stage) the frame sitting in `self.buf`: LSN bump and
    /// counters happen here — staging changes when bytes hit the file,
    /// never their content or order — then the policy decides between
    /// a direct `write_all` (+fsync) and the coalescing buffer.
    fn write_frame(&mut self, frame_len: u64) -> Result<()> {
        self.seg_bytes += frame_len;
        self.next_lsn += 1;
        self.dirty = true;
        if let Some(m) = &self.metrics {
            Counters::inc(&m.wal_records, 1);
            Counters::inc(&m.wal_bytes, frame_len);
        }
        match self.fsync {
            FsyncPolicy::Always => {
                // Per-record fsync leaves nothing to coalesce with:
                // staging would only delay the promised sync.
                self.file
                    .write_all(&self.buf)
                    .context("appending WAL frame")?;
                self.note_write(1, frame_len);
                self.sync()?;
            }
            FsyncPolicy::Interval(iv) => {
                self.stage_frame()?;
                if self.last_sync.elapsed() >= iv {
                    self.sync()?;
                }
            }
            FsyncPolicy::Off => self.stage_frame()?,
        }
        Ok(())
    }

    /// Move the encoded frame into the staging buffer; flush it as one
    /// coalesced `write_all` once either cap trips.
    fn stage_frame(&mut self) -> Result<()> {
        self.staged.extend_from_slice(&self.buf);
        self.staged_frames += 1;
        if self.staged.len() >= COALESCE_MAX_BYTES || self.staged_frames >= COALESCE_MAX_FRAMES {
            self.flush_staged()?;
        }
        Ok(())
    }

    /// Ship every staged frame in one `write_all`. No-op when nothing
    /// is staged.
    pub fn flush_staged(&mut self) -> Result<()> {
        if self.staged_frames == 0 {
            return Ok(());
        }
        self.file
            .write_all(&self.staged)
            .context("appending coalesced WAL frames")?;
        let frames = self.staged_frames;
        let bytes = self.staged.len() as u64;
        self.staged.clear();
        self.staged_frames = 0;
        self.note_write(frames, bytes);
        if frames >= 2 {
            self.seg_stats.coalesced_writes += 1;
            self.seg_stats.coalesced_frames += frames;
            if let Some(m) = &self.metrics {
                Counters::inc(&m.wal_coalesced_writes, 1);
                Counters::inc(&m.wal_coalesced_frames, frames);
            }
        }
        Ok(())
    }

    fn note_write(&mut self, frames: u64, bytes: u64) {
        self.seg_stats.writes += 1;
        self.seg_stats.frames += frames;
        self.seg_stats.bytes += bytes;
    }

    /// Force dirty bytes to disk (barrier semantics: drains, snapshots
    /// and shutdown call this regardless of policy). Staged frames are
    /// flushed first — an fsync of a file the frames never reached
    /// would be a durability lie.
    pub fn sync(&mut self) -> Result<()> {
        self.flush_staged()?;
        if !self.dirty {
            return Ok(());
        }
        let t0 = Instant::now();
        self.file.sync_data().context("fsyncing WAL segment")?;
        let dt = t0.elapsed().as_nanos() as u64;
        self.dirty = false;
        self.last_sync = Instant::now();
        if let Some(m) = &self.metrics {
            Counters::inc(&m.wal_fsyncs, 1);
            m.wal_fsync.record_ns(dt);
            // Span tracing reads this gauge as the shard's `t_fsync`
            // stage (resolve→fsync lag under coalesced policies).
            m.last_fsync_ns
                .store(crate::telemetry::now_ns(), std::sync::atomic::Ordering::Relaxed);
        }
        Ok(())
    }

    /// Rotate to a fresh segment once the current one is full. The old
    /// segment is synced first (which also flushes staged frames into
    /// it — they carry LSNs the old segment's name range owns) so
    /// rotation never leaves a dirty immutable file behind, and its
    /// sidecar stats entry is finalized.
    fn maybe_rotate(&mut self) -> Result<()> {
        if self.seg_bytes < self.segment_bytes {
            return Ok(());
        }
        self.sync()?;
        self.persist_stats()?;
        let (file, seg_bytes) = Self::create_segment(&self.root, self.shard, self.next_lsn)?;
        self.file = file;
        self.seg_bytes = seg_bytes;
        self.seg_first_lsn = self.next_lsn;
        self.seg_stats = SegmentWriteStats::default();
        if let Some(m) = &self.metrics {
            Counters::inc(&m.wal_rotations, 1);
        }
        Ok(())
    }

    /// Write the per-segment stats sidecar atomically (temp + rename).
    /// Called at rotation, barriers and drop — not per append.
    fn persist_stats(&mut self) -> Result<()> {
        self.stats_map.insert(self.seg_first_lsn, self.seg_stats);
        let path = stats_path(&self.root, self.shard);
        let tmp = path.with_extension("json.tmp");
        let mut s = String::from("{\"wal_stats\":\"fast-wal-v1\",\"segments\":{");
        for (i, (first_lsn, st)) in self.stats_map.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\"{first_lsn:016x}\":{{\"writes\":{},\"frames\":{},\"bytes\":{},\
                 \"coalesced_writes\":{},\"coalesced_frames\":{}}}",
                st.writes, st.frames, st.bytes, st.coalesced_writes, st.coalesced_frames
            ));
        }
        s.push_str("}}\n");
        std::fs::write(&tmp, s).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} into place", path.display()))?;
        Ok(())
    }
}

impl Drop for ShardWal {
    fn drop(&mut self) {
        let _ = self.sync();
        let _ = self.persist_stats();
    }
}

impl CommitListener for ShardWal {
    fn on_commit(&mut self, commit: &Commit, kind: BatchKind, operands: &[u32]) -> Result<()> {
        self.append_batch(commit, kind, operands)
    }

    fn on_write(&mut self, row: usize, value: u32, committed_seq: u64) -> Result<()> {
        self.append_write(row, value, committed_seq)
    }

    fn on_barrier(&mut self) -> Result<()> {
        self.sync()?;
        self.persist_stats()
    }

    fn on_quiescent(&mut self) -> Result<()> {
        // The worker's queue just drained: ship the staged frames so
        // the coalescing window is bounded by the active burst, not by
        // idle time. fsync pacing stays with the policy.
        self.flush_staged()
    }

    fn flush_due(&self) -> Option<Instant> {
        // Interval policy with dirty bytes: the worker must force a
        // sync once the window lapses, or an idle tail would sit on
        // the OS writeback horizon instead of the promised interval.
        match self.fsync {
            FsyncPolicy::Interval(iv) if self.dirty => Some(self.last_sync + iv),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{check, Gen};

    fn demo_batch(lsn: u64, seq: u64, ops: Vec<(u32, u32)>) -> WalRecord {
        WalRecord {
            shard: 2,
            lsn,
            commit_seq: seq,
            payload: WalPayload::Batch {
                seal_reason: SealReason::Full,
                kind: BatchKind::Add,
                ops,
            },
        }
    }

    #[test]
    fn frame_round_trips() {
        let rec = demo_batch(7, 3, vec![(0, 5), (9, 1000)]);
        let mut buf = Vec::new();
        let n = rec.encode_into(&mut buf);
        assert_eq!(n, buf.len());
        let payload = &buf[8..];
        assert_eq!(
            u32::from_le_bytes(buf[4..8].try_into().unwrap()),
            crate::util::crc32::crc32(payload)
        );
        assert_eq!(WalRecord::decode(payload).unwrap(), rec);

        let w = WalRecord {
            shard: 0,
            lsn: 1,
            commit_seq: 0,
            payload: WalPayload::Write { row: 4, value: 0xAB },
        };
        let mut buf = Vec::new();
        w.encode_into(&mut buf);
        assert_eq!(WalRecord::decode(&buf[8..]).unwrap(), w);
    }

    #[test]
    fn prop_records_round_trip() {
        check("wal frame round trip", 300, |g| {
            let rec = random_record(g);
            let mut buf = Vec::new();
            rec.encode_into(&mut buf);
            WalRecord::decode(&buf[8..]).ok() == Some(rec)
        });
    }

    fn random_record(g: &mut Gen) -> WalRecord {
        let shard = g.u32_below(8);
        let lsn = g.u64_any() | 1;
        let seq = g.u64_any();
        if g.bool() {
            let seal = *g.choose(&[
                SealReason::Full,
                SealReason::KindChange,
                SealReason::Deadline,
                SealReason::Forced,
            ]);
            let kind =
                *g.choose(&[BatchKind::Add, BatchKind::And, BatchKind::Or, BatchKind::Xor]);
            let ops = g.vec_of(16, |g| (g.u32_below(1 << 16), g.u32_any()));
            WalRecord {
                shard,
                lsn,
                commit_seq: seq,
                payload: WalPayload::Batch { seal_reason: seal, kind, ops },
            }
        } else {
            WalRecord {
                shard,
                lsn,
                commit_seq: seq,
                payload: WalPayload::Write { row: g.u32_below(1 << 16), value: g.u32_any() },
            }
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let d = std::env::temp_dir()
            .join(format!("fast-wal-{tag}-{}-{nanos}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn demo_commit(seq: u64) -> Commit {
        Commit {
            shard: 0,
            commit_seq: seq,
            seal_reason: SealReason::Forced,
            rows: 1,
            requests: 1,
            rows_active: 1,
            modeled_ns: 0.0,
            cycles: 0,
            banks_active: 1,
        }
    }

    /// Read every record of shard 0's log back, in order.
    fn read_all(dir: &Path) -> Vec<WalRecord> {
        let mut out = Vec::new();
        for seg in segment::list_segments(dir, 0).unwrap() {
            let mut r = SegmentReader::open(&seg.path, 0).unwrap();
            while let Some(rec) = r.next_record() {
                out.push(rec);
            }
            assert!(r.torn().is_none(), "clean log must scan cleanly");
        }
        out
    }

    #[test]
    fn off_policy_coalesces_frames_and_recovery_sees_them_all() {
        let dir = tmpdir("coalesce");
        let m = Arc::new(ShardCounters::default());
        let mut wal = ShardWal::open(
            &dir,
            0,
            8,
            1,
            FsyncPolicy::Off,
            1 << 20,
            Some(Arc::clone(&m)),
        )
        .unwrap();
        let n = 10u64;
        for i in 0..n {
            wal.append_batch(&demo_commit(i + 1), BatchKind::Add, &[3]).unwrap();
        }
        // All frames staged, none on disk yet — the segment is still
        // just its header.
        let segs = segment::list_segments(&dir, 0).unwrap();
        assert_eq!(segs[0].bytes, SEGMENT_HEADER_LEN, "frames must be staged, not written");
        // A barrier ships them as ONE coalesced write.
        wal.sync().unwrap();
        let snap = m.snapshot();
        assert_eq!(snap.wal_records, n);
        assert_eq!(snap.wal_coalesced_writes, 1);
        assert_eq!(snap.wal_coalesced_frames, n);
        drop(wal);
        let recs = read_all(&dir);
        assert_eq!(recs.len(), n as usize, "recovery must see every staged frame");
        assert_eq!(
            recs.iter().map(|r| r.lsn).collect::<Vec<_>>(),
            (1..=n).collect::<Vec<_>>(),
            "coalescing must not reorder or gap the LSN stream"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn frame_cap_forces_a_flush_mid_burst() {
        let dir = tmpdir("cap");
        let m = Arc::new(ShardCounters::default());
        let mut wal = ShardWal::open(
            &dir,
            0,
            8,
            1,
            FsyncPolicy::Off,
            1 << 20,
            Some(Arc::clone(&m)),
        )
        .unwrap();
        let n = COALESCE_MAX_FRAMES + 6;
        for i in 0..n {
            wal.append_batch(&demo_commit(i + 1), BatchKind::Add, &[3]).unwrap();
        }
        // The cap tripped once: exactly COALESCE_MAX_FRAMES frames hit
        // the file in one write; the remainder are still staged.
        let snap = m.snapshot();
        assert_eq!(snap.wal_coalesced_writes, 1);
        assert_eq!(snap.wal_coalesced_frames, COALESCE_MAX_FRAMES);
        drop(wal); // drop flushes the tail
        assert_eq!(read_all(&dir).len(), n as usize);
        let snap = m.snapshot();
        assert_eq!(snap.wal_coalesced_writes, 2);
        assert_eq!(snap.wal_coalesced_frames, n);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn always_policy_never_stages() {
        let dir = tmpdir("always");
        let m = Arc::new(ShardCounters::default());
        let mut wal = ShardWal::open(
            &dir,
            0,
            8,
            1,
            FsyncPolicy::Always,
            1 << 20,
            Some(Arc::clone(&m)),
        )
        .unwrap();
        for i in 0..5u64 {
            wal.append_batch(&demo_commit(i + 1), BatchKind::Add, &[3]).unwrap();
        }
        let snap = m.snapshot();
        assert_eq!(snap.wal_coalesced_writes, 0, "always-policy frames ship one by one");
        assert_eq!(snap.wal_fsyncs, 5, "one fsync per record");
        drop(wal);
        assert_eq!(read_all(&dir).len(), 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sidecar_stats_round_trip_and_survive_reopen() {
        let dir = tmpdir("sidecar");
        let mut wal =
            ShardWal::open(&dir, 0, 8, 1, FsyncPolicy::Off, 1 << 20, None).unwrap();
        for i in 0..4u64 {
            wal.append_batch(&demo_commit(i + 1), BatchKind::Add, &[3]).unwrap();
        }
        let next = wal.next_lsn();
        drop(wal);
        let stats = load_segment_stats(&dir, 0).unwrap();
        let seg = stats.get(&1).copied().unwrap();
        assert_eq!(seg.frames, 4);
        assert_eq!(seg.writes, 1, "one coalesced write shipped the burst");
        assert_eq!(seg.coalesced_writes, 1);
        assert_eq!(seg.coalesced_frames, 4);
        assert!(seg.bytes > 0);
        // Reopen and append more: the same segment's entry accumulates
        // instead of resetting.
        let mut wal =
            ShardWal::open(&dir, 0, 8, next, FsyncPolicy::Off, 1 << 20, None).unwrap();
        wal.append_batch(&demo_commit(5), BatchKind::Add, &[3]).unwrap();
        drop(wal);
        let stats = load_segment_stats(&dir, 0).unwrap();
        let seg = stats.get(&1).copied().unwrap();
        assert_eq!(seg.frames, 5);
        assert_eq!(seg.writes, 2);
        // The sidecar never pollutes the segment listing.
        assert_eq!(segment::list_segments(&dir, 0).unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn coalesce_rows_flag_sidecar_less_dirs_explicitly() {
        let dir = tmpdir("nosidecar");
        // A WAL directory written by a pre-sidecar build: segments
        // exist, coalesce.json does not.
        let mut wal =
            ShardWal::open(&dir, 0, 8, 1, FsyncPolicy::Off, 1 << 20, None).unwrap();
        wal.append_batch(&demo_commit(1), BatchKind::Add, &[3]).unwrap();
        wal.sync().unwrap();
        drop(wal);
        std::fs::remove_file(stats_path(&dir, 0)).unwrap();
        assert!(!has_segment_stats(&dir, 0));
        let rows = coalesce_rows(&dir, 0);
        assert_eq!(rows.len(), 1, "absence must yield one explicit row, not silence");
        assert_eq!(rows[0].0, "shard 0 coalesce");
        assert_eq!(rows[0].1, "(no sidecar)");
        // Once a sidecar-writing build touches the dir, real per-segment
        // rows replace the placeholder.
        let mut wal =
            ShardWal::open(&dir, 0, 8, 2, FsyncPolicy::Off, 1 << 20, None).unwrap();
        wal.append_batch(&demo_commit(2), BatchKind::Add, &[3]).unwrap();
        drop(wal);
        assert!(has_segment_stats(&dir, 0));
        let rows = coalesce_rows(&dir, 0);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].0.starts_with("shard 0 seg-"), "key names the segment: {}", rows[0].0);
        assert!(rows[0].1.contains("writes |"), "row carries write stats: {}", rows[0].1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        assert!(WalRecord::decode(&[]).is_err());
        assert!(WalRecord::decode(&[9; 27]).is_err(), "bad record type");
        let rec = demo_batch(1, 1, vec![(0, 1)]);
        let mut buf = Vec::new();
        rec.encode_into(&mut buf);
        // Length/ops mismatch.
        assert!(WalRecord::decode(&buf[8..buf.len() - 1]).is_err());
        // Bad seal byte.
        let mut p = buf[8..].to_vec();
        p[21] = 99;
        assert!(WalRecord::decode(&p).is_err());
    }
}
