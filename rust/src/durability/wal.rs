//! The segmented binary write-ahead log: frame codec, torn-tail-aware
//! segment reader, and the per-shard appender that rides the engine's
//! group-commit seals.
//!
//! ## Frame format (`fast-wal-v1`)
//!
//! ```text
//! frame   := len:u32 | crc:u32 | payload        (len = payload bytes,
//!                                                crc = CRC32(payload))
//! payload := rtype:u8 | shard:u32 | lsn:u64 | commit_seq:u64
//!          | seal_reason:u8 | kind:u8 | nops:u32 | nops×(row:u32, val:u32)
//! ```
//!
//! All integers little-endian. `rtype` 1 = sealed-batch commit (`ops`
//! are the batch's non-identity `(local_row, operand)` pairs after
//! coalescing), `rtype` 2 = conventional-port absolute write (`nops`
//! = 1, `commit_seq` = the shard's last committed seq at log time —
//! writes do not mint commit seqs). `lsn` is the shard's own log
//! sequence number, strictly increasing across every record the shard
//! ever logs; it is the recovery watermark (commit_seq alone cannot
//! order writes between two batch commits).
//!
//! ## Group-commit alignment
//!
//! One engine seal = one [`ShardWal::append_batch`] = one frame encoded
//! into a reusable buffer, ONE `write_all`, and at most one fsync
//! (per the [`FsyncPolicy`]) — durability amortizes exactly like the
//! group commit it rides; there is never a syscall per request.
//!
//! ## Torn tails
//!
//! [`SegmentReader`] stops at the first bad frame (short header, bogus
//! length, CRC mismatch, undecodable payload) and reports the byte
//! offset of the good prefix; recovery truncates there (`repair`) so
//! the log is always a prefix of what was appended — never a
//! reordering, never a gap.

use std::fs::{File, OpenOptions};
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context};

use crate::coordinator::batcher::SealReason;
use crate::coordinator::engine::CommitListener;
use crate::coordinator::request::{BatchKind, Commit};
use crate::metrics::{Counters, ShardCounters};
use crate::Result;

use super::segment::{
    self, encode_segment_header, read_segment_header, SEGMENT_HEADER_LEN,
};

/// Upper bound on one frame's payload (sanity cap so a corrupt length
/// field can never trigger a giant allocation).
pub const MAX_PAYLOAD: u32 = 1 << 26; // 64 MiB

/// Fixed payload bytes before the ops array.
const PAYLOAD_FIXED: usize = 1 + 4 + 8 + 8 + 1 + 1 + 4;

/// When to fsync the shard's segment file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every appended record: a resolved ticket implies
    /// the commit is on disk. Safest, slowest.
    Always,
    /// fsync at most once per interval (checked at append time) plus
    /// at every barrier (drain / snapshot / shutdown). A crash can
    /// lose up to one interval of *acknowledged* commits; recovery is
    /// still prefix-consistent.
    Interval(Duration),
    /// Never fsync explicitly; the OS flushes on its own schedule.
    /// Survives process kills (data reached the kernel), not power
    /// loss.
    Off,
}

impl FsyncPolicy {
    /// Parse the CLI spelling: `always` | `interval` | `off`.
    pub fn parse(s: &str, interval: Duration) -> Result<FsyncPolicy> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "interval" => Ok(FsyncPolicy::Interval(interval)),
            "off" => Ok(FsyncPolicy::Off),
            other => bail!("unknown fsync policy {other:?} (always|interval|off)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Interval(_) => "interval",
            FsyncPolicy::Off => "off",
        }
    }
}

/// What one WAL record carries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalPayload {
    /// A sealed batch the backend applied: the commit's seal reason,
    /// batch kind, and the non-identity `(local_row, operand)` pairs.
    Batch {
        seal_reason: SealReason,
        kind: BatchKind,
        ops: Vec<(u32, u32)>,
    },
    /// A conventional-port absolute write.
    Write { row: u32, value: u32 },
}

/// One decoded WAL record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalRecord {
    pub shard: u32,
    /// Shard-local log sequence number (strictly increasing).
    pub lsn: u64,
    /// Batch records: the minted commit seq. Write records: the
    /// shard's last committed seq when the write was logged.
    pub commit_seq: u64,
    pub payload: WalPayload,
}

fn seal_to_u8(r: SealReason) -> u8 {
    match r {
        SealReason::Full => 0,
        SealReason::KindChange => 1,
        SealReason::Deadline => 2,
        SealReason::Forced => 3,
    }
}

fn seal_from_u8(b: u8) -> Result<SealReason> {
    Ok(match b {
        0 => SealReason::Full,
        1 => SealReason::KindChange,
        2 => SealReason::Deadline,
        3 => SealReason::Forced,
        other => bail!("bad seal reason byte {other}"),
    })
}

fn kind_to_u8(k: BatchKind) -> u8 {
    match k {
        BatchKind::Add => 0,
        BatchKind::And => 1,
        BatchKind::Or => 2,
        BatchKind::Xor => 3,
    }
}

fn kind_from_u8(b: u8) -> Result<BatchKind> {
    Ok(match b {
        0 => BatchKind::Add,
        1 => BatchKind::And,
        2 => BatchKind::Or,
        3 => BatchKind::Xor,
        other => bail!("bad batch kind byte {other}"),
    })
}

/// Encode one complete frame (len + crc + payload) into `buf` from
/// streamed ops — the shared encoder behind [`WalRecord::encode_into`]
/// and the appender's allocation-free hot path (the ops iterate
/// straight out of the batch's operand vector; nothing is collected).
/// Returns the frame length in bytes.
#[allow(clippy::too_many_arguments)]
fn encode_frame(
    buf: &mut Vec<u8>,
    shard: u32,
    lsn: u64,
    commit_seq: u64,
    rtype: u8,
    seal: u8,
    kind: u8,
    nops: usize,
    ops: impl Iterator<Item = (u32, u32)>,
) -> usize {
    let start = buf.len();
    let len = PAYLOAD_FIXED + nops * 8;
    buf.reserve(8 + len);
    buf.extend_from_slice(&(len as u32).to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]); // crc backfilled below
    let payload_at = buf.len();
    buf.push(rtype);
    buf.extend_from_slice(&shard.to_le_bytes());
    buf.extend_from_slice(&lsn.to_le_bytes());
    buf.extend_from_slice(&commit_seq.to_le_bytes());
    buf.push(seal);
    buf.push(kind);
    buf.extend_from_slice(&(nops as u32).to_le_bytes());
    for (row, val) in ops {
        buf.extend_from_slice(&row.to_le_bytes());
        buf.extend_from_slice(&val.to_le_bytes());
    }
    debug_assert_eq!(buf.len() - payload_at, len, "nops disagrees with the ops iterator");
    let crc = crate::util::crc32::crc32(&buf[payload_at..]);
    buf[payload_at - 4..payload_at].copy_from_slice(&crc.to_le_bytes());
    buf.len() - start
}

impl WalRecord {
    /// Append this record's complete frame (len + crc + payload) to
    /// `buf`. Returns the frame length in bytes.
    pub fn encode_into(&self, buf: &mut Vec<u8>) -> usize {
        match &self.payload {
            WalPayload::Batch { seal_reason, kind, ops } => encode_frame(
                buf,
                self.shard,
                self.lsn,
                self.commit_seq,
                1,
                seal_to_u8(*seal_reason),
                kind_to_u8(*kind),
                ops.len(),
                ops.iter().copied(),
            ),
            WalPayload::Write { row, value } => encode_frame(
                buf,
                self.shard,
                self.lsn,
                self.commit_seq,
                2,
                0,
                0,
                1,
                std::iter::once((*row, *value)),
            ),
        }
    }

    /// Decode one frame payload (after the CRC already verified).
    pub fn decode(payload: &[u8]) -> Result<WalRecord> {
        ensure!(payload.len() >= PAYLOAD_FIXED, "payload too short ({} bytes)", payload.len());
        let u32_at = |at: usize| u32::from_le_bytes(payload[at..at + 4].try_into().expect("4"));
        let u64_at = |at: usize| u64::from_le_bytes(payload[at..at + 8].try_into().expect("8"));
        let rtype = payload[0];
        let shard = u32_at(1);
        let lsn = u64_at(5);
        let commit_seq = u64_at(13);
        let seal = payload[21];
        let kind = payload[22];
        let nops = u32_at(23) as usize;
        ensure!(
            payload.len() == PAYLOAD_FIXED + nops * 8,
            "payload length {} != header-implied {}",
            payload.len(),
            PAYLOAD_FIXED + nops * 8
        );
        let pair_at =
            |i: usize| (u32_at(PAYLOAD_FIXED + i * 8), u32_at(PAYLOAD_FIXED + i * 8 + 4));
        let record = match rtype {
            1 => {
                let ops = (0..nops).map(pair_at).collect();
                WalRecord {
                    shard,
                    lsn,
                    commit_seq,
                    payload: WalPayload::Batch {
                        seal_reason: seal_from_u8(seal)?,
                        kind: kind_from_u8(kind)?,
                        ops,
                    },
                }
            }
            2 => {
                ensure!(nops == 1, "write record must carry exactly one op, got {nops}");
                let (row, value) = pair_at(0);
                WalRecord { shard, lsn, commit_seq, payload: WalPayload::Write { row, value } }
            }
            other => bail!("bad record type byte {other}"),
        };
        Ok(record)
    }
}

// ---------------------------------------------------------------------------
// Segment reader
// ---------------------------------------------------------------------------

/// Why a segment scan stopped before end-of-file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TornTail {
    /// Byte offset of the first bad frame — the length of the good
    /// prefix, the offset `repair` truncates at.
    pub offset: u64,
    pub reason: String,
}

/// Sequential reader over one segment file. Stops (without erroring)
/// at the first bad frame and reports it via [`Self::torn`]; a clean
/// EOF leaves `torn` unset.
pub struct SegmentReader {
    r: BufReader<File>,
    path: PathBuf,
    shard: u32,
    /// Bytes of validated frames consumed so far (header included).
    offset: u64,
    torn: Option<TornTail>,
    done: bool,
}

impl SegmentReader {
    /// Open a segment and validate its header. A header that is
    /// missing, short, or foreign is an `Err` — the caller decides
    /// whether that means "torn at byte 0" (repair removes the file)
    /// or corruption.
    pub fn open(path: &Path, expect_shard: usize) -> Result<SegmentReader> {
        let file =
            File::open(path).with_context(|| format!("opening segment {}", path.display()))?;
        let mut r = BufReader::new(file);
        let shard = read_segment_header(&mut r, path)?;
        ensure!(
            shard as usize == expect_shard,
            "{}: segment claims shard {shard}, found in shard {expect_shard}'s directory",
            path.display()
        );
        Ok(SegmentReader {
            r,
            path: path.to_path_buf(),
            shard,
            offset: SEGMENT_HEADER_LEN,
            torn: None,
            done: false,
        })
    }

    /// The first bad frame, if the scan hit one.
    pub fn torn(&self) -> Option<&TornTail> {
        self.torn.as_ref()
    }

    /// Bytes of good frames consumed (the truncation point on repair).
    pub fn offset(&self) -> u64 {
        self.offset
    }

    fn mark_torn(&mut self, reason: String) {
        self.torn = Some(TornTail { offset: self.offset, reason });
        self.done = true;
    }

    /// Next record, or `None` at clean EOF / first bad frame.
    pub fn next_record(&mut self) -> Option<WalRecord> {
        if self.done {
            return None;
        }
        let mut head = [0u8; 8];
        match read_full(&mut self.r, &mut head) {
            Ok(0) => {
                self.done = true;
                return None;
            }
            Ok(8) => {}
            Ok(n) => {
                self.mark_torn(format!("frame header truncated ({n} of 8 bytes)"));
                return None;
            }
            Err(e) => {
                self.mark_torn(format!("reading frame header: {e}"));
                return None;
            }
        }
        let len = u32::from_le_bytes(head[..4].try_into().expect("4"));
        let crc = u32::from_le_bytes(head[4..].try_into().expect("4"));
        if len < PAYLOAD_FIXED as u32 || len > MAX_PAYLOAD {
            self.mark_torn(format!("implausible frame length {len}"));
            return None;
        }
        let mut payload = vec![0u8; len as usize];
        match read_full(&mut self.r, &mut payload) {
            Ok(n) if n == len as usize => {}
            Ok(n) => {
                self.mark_torn(format!("frame payload truncated ({n} of {len} bytes)"));
                return None;
            }
            Err(e) => {
                self.mark_torn(format!("reading frame payload: {e}"));
                return None;
            }
        }
        if crate::util::crc32::crc32(&payload) != crc {
            self.mark_torn("frame CRC mismatch".to_string());
            return None;
        }
        match WalRecord::decode(&payload) {
            Ok(rec) => {
                if rec.shard != self.shard {
                    self.mark_torn(format!(
                        "record claims shard {}, segment {} belongs to shard {}",
                        rec.shard,
                        self.path.display(),
                        self.shard
                    ));
                    return None;
                }
                self.offset += 8 + len as u64;
                Some(rec)
            }
            Err(e) => {
                self.mark_torn(format!("undecodable payload: {e}"));
                None
            }
        }
    }
}

/// `read_exact` that reports how many bytes it got instead of erroring
/// on a short tail (torn tails are expected, not exceptional).
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

// ---------------------------------------------------------------------------
// Appender
// ---------------------------------------------------------------------------

/// The per-shard WAL appender: owned by the shard's worker thread,
/// driven through the engine's [`CommitListener`] hook so every record
/// lands *after* the backend apply and *before* any completion ticket
/// resolves. Rotation, fsync policy and metrics are internal.
pub struct ShardWal {
    root: PathBuf,
    shard: usize,
    q: usize,
    fsync: FsyncPolicy,
    segment_bytes: u64,
    file: File,
    seg_bytes: u64,
    next_lsn: u64,
    last_sync: Instant,
    dirty: bool,
    /// Reusable frame-encode buffer (no allocation on the hot path).
    buf: Vec<u8>,
    metrics: Option<Arc<ShardCounters>>,
}

impl ShardWal {
    /// Open (or create) the shard's log for appending at `next_lsn`.
    /// Recovery must already have truncated any torn tail — this
    /// appends blindly to the newest segment.
    pub fn open(
        root: &Path,
        shard: usize,
        q: usize,
        next_lsn: u64,
        fsync: FsyncPolicy,
        segment_bytes: u64,
        metrics: Option<Arc<ShardCounters>>,
    ) -> Result<ShardWal> {
        ensure!(next_lsn >= 1, "lsn space starts at 1");
        ensure!(segment_bytes >= 1024, "segment_bytes must be >= 1024");
        let sdir = segment::shard_dir(root, shard);
        std::fs::create_dir_all(&sdir)
            .with_context(|| format!("creating {}", sdir.display()))?;
        let segs = segment::list_segments(root, shard)?;
        let (file, seg_bytes) = match segs.last() {
            Some(last) if last.bytes >= SEGMENT_HEADER_LEN => {
                let f = OpenOptions::new()
                    .append(true)
                    .open(&last.path)
                    .with_context(|| format!("opening {} for append", last.path.display()))?;
                (f, last.bytes)
            }
            _ => {
                // No segment yet (or a headerless stub recovery chose
                // not to keep): start a fresh one at next_lsn.
                if let Some(stub) = segs.last() {
                    let _ = std::fs::remove_file(&stub.path);
                }
                Self::create_segment(root, shard, next_lsn)?
            }
        };
        Ok(ShardWal {
            root: root.to_path_buf(),
            shard,
            q,
            fsync,
            segment_bytes,
            file,
            seg_bytes,
            next_lsn,
            last_sync: Instant::now(),
            dirty: false,
            buf: Vec::with_capacity(4096),
            metrics,
        })
    }

    fn create_segment(root: &Path, shard: usize, first_lsn: u64) -> Result<(File, u64)> {
        let path = segment::segment_path(root, shard, first_lsn);
        let mut f = OpenOptions::new()
            .create_new(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("creating segment {}", path.display()))?;
        f.write_all(&encode_segment_header(shard))?;
        Ok((f, SEGMENT_HEADER_LEN))
    }

    /// The next LSN this appender will assign.
    pub fn next_lsn(&self) -> u64 {
        self.next_lsn
    }

    /// Log one sealed batch: the commit metadata plus the batch's
    /// non-identity `(row, operand)` pairs, streamed straight from the
    /// dense operand vector into the reusable frame buffer (no
    /// intermediate allocation). One buffered frame, one `write_all`,
    /// at most one fsync — aligned with the group-commit seal this
    /// rides.
    pub fn append_batch(
        &mut self,
        commit: &Commit,
        kind: BatchKind,
        operands: &[u32],
    ) -> Result<()> {
        self.maybe_rotate()?;
        let ident = kind.identity(self.q);
        // A batch whose every coalesced operand cancelled to identity
        // still logs (zero ops) so commit_seq stays dense in the log.
        let nops = operands.iter().filter(|&&o| o != ident).count();
        self.buf.clear();
        let frame_len = encode_frame(
            &mut self.buf,
            self.shard as u32,
            self.next_lsn,
            commit.commit_seq,
            1,
            seal_to_u8(commit.seal_reason),
            kind_to_u8(kind),
            nops,
            operands
                .iter()
                .enumerate()
                .filter(|&(_, &o)| o != ident)
                .map(|(r, &o)| (r as u32, o)),
        );
        self.write_frame(frame_len as u64)
    }

    /// Log one conventional-port write. `committed_seq` is the shard's
    /// last committed seq (writes do not mint seqs; the LSN orders
    /// them between commits).
    pub fn append_write(&mut self, row: usize, value: u32, committed_seq: u64) -> Result<()> {
        self.maybe_rotate()?;
        self.buf.clear();
        let frame_len = encode_frame(
            &mut self.buf,
            self.shard as u32,
            self.next_lsn,
            committed_seq,
            2,
            0,
            0,
            1,
            std::iter::once((row as u32, value)),
        );
        self.write_frame(frame_len as u64)
    }

    /// Ship the frame sitting in `self.buf`: one `write_all`, LSN
    /// bump, counters, and the policy-driven fsync.
    fn write_frame(&mut self, frame_len: u64) -> Result<()> {
        self.file
            .write_all(&self.buf)
            .context("appending WAL frame")?;
        self.seg_bytes += frame_len;
        self.next_lsn += 1;
        self.dirty = true;
        if let Some(m) = &self.metrics {
            Counters::inc(&m.wal_records, 1);
            Counters::inc(&m.wal_bytes, frame_len);
        }
        match self.fsync {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::Interval(iv) => {
                if self.last_sync.elapsed() >= iv {
                    self.sync()?;
                }
            }
            FsyncPolicy::Off => {}
        }
        Ok(())
    }

    /// Force dirty bytes to disk (barrier semantics: drains, snapshots
    /// and shutdown call this regardless of policy).
    pub fn sync(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let t0 = Instant::now();
        self.file.sync_data().context("fsyncing WAL segment")?;
        let dt = t0.elapsed().as_nanos() as u64;
        self.dirty = false;
        self.last_sync = Instant::now();
        if let Some(m) = &self.metrics {
            Counters::inc(&m.wal_fsyncs, 1);
            m.wal_fsync.record_ns(dt);
        }
        Ok(())
    }

    /// Rotate to a fresh segment once the current one is full. The old
    /// segment is synced first so rotation never leaves a dirty
    /// immutable file behind.
    fn maybe_rotate(&mut self) -> Result<()> {
        if self.seg_bytes < self.segment_bytes {
            return Ok(());
        }
        self.sync()?;
        let (file, seg_bytes) = Self::create_segment(&self.root, self.shard, self.next_lsn)?;
        self.file = file;
        self.seg_bytes = seg_bytes;
        if let Some(m) = &self.metrics {
            Counters::inc(&m.wal_rotations, 1);
        }
        Ok(())
    }
}

impl Drop for ShardWal {
    fn drop(&mut self) {
        let _ = self.sync();
    }
}

impl CommitListener for ShardWal {
    fn on_commit(&mut self, commit: &Commit, kind: BatchKind, operands: &[u32]) -> Result<()> {
        self.append_batch(commit, kind, operands)
    }

    fn on_write(&mut self, row: usize, value: u32, committed_seq: u64) -> Result<()> {
        self.append_write(row, value, committed_seq)
    }

    fn on_barrier(&mut self) -> Result<()> {
        self.sync()
    }

    fn flush_due(&self) -> Option<Instant> {
        // Interval policy with dirty bytes: the worker must force a
        // sync once the window lapses, or an idle tail would sit on
        // the OS writeback horizon instead of the promised interval.
        match self.fsync {
            FsyncPolicy::Interval(iv) if self.dirty => Some(self.last_sync + iv),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{check, Gen};

    fn demo_batch(lsn: u64, seq: u64, ops: Vec<(u32, u32)>) -> WalRecord {
        WalRecord {
            shard: 2,
            lsn,
            commit_seq: seq,
            payload: WalPayload::Batch {
                seal_reason: SealReason::Full,
                kind: BatchKind::Add,
                ops,
            },
        }
    }

    #[test]
    fn frame_round_trips() {
        let rec = demo_batch(7, 3, vec![(0, 5), (9, 1000)]);
        let mut buf = Vec::new();
        let n = rec.encode_into(&mut buf);
        assert_eq!(n, buf.len());
        let payload = &buf[8..];
        assert_eq!(
            u32::from_le_bytes(buf[4..8].try_into().unwrap()),
            crate::util::crc32::crc32(payload)
        );
        assert_eq!(WalRecord::decode(payload).unwrap(), rec);

        let w = WalRecord {
            shard: 0,
            lsn: 1,
            commit_seq: 0,
            payload: WalPayload::Write { row: 4, value: 0xAB },
        };
        let mut buf = Vec::new();
        w.encode_into(&mut buf);
        assert_eq!(WalRecord::decode(&buf[8..]).unwrap(), w);
    }

    #[test]
    fn prop_records_round_trip() {
        check("wal frame round trip", 300, |g| {
            let rec = random_record(g);
            let mut buf = Vec::new();
            rec.encode_into(&mut buf);
            WalRecord::decode(&buf[8..]).ok() == Some(rec)
        });
    }

    fn random_record(g: &mut Gen) -> WalRecord {
        let shard = g.u32_below(8);
        let lsn = g.u64_any() | 1;
        let seq = g.u64_any();
        if g.bool() {
            let seal = *g.choose(&[
                SealReason::Full,
                SealReason::KindChange,
                SealReason::Deadline,
                SealReason::Forced,
            ]);
            let kind =
                *g.choose(&[BatchKind::Add, BatchKind::And, BatchKind::Or, BatchKind::Xor]);
            let ops = g.vec_of(16, |g| (g.u32_below(1 << 16), g.u32_any()));
            WalRecord {
                shard,
                lsn,
                commit_seq: seq,
                payload: WalPayload::Batch { seal_reason: seal, kind, ops },
            }
        } else {
            WalRecord {
                shard,
                lsn,
                commit_seq: seq,
                payload: WalPayload::Write { row: g.u32_below(1 << 16), value: g.u32_any() },
            }
        }
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        assert!(WalRecord::decode(&[]).is_err());
        assert!(WalRecord::decode(&[9; 27]).is_err(), "bad record type");
        let rec = demo_batch(1, 1, vec![(0, 1)]);
        let mut buf = Vec::new();
        rec.encode_into(&mut buf);
        // Length/ops mismatch.
        assert!(WalRecord::decode(&buf[8..buf.len() - 1]).is_err());
        // Bad seal byte.
        let mut p = buf[8..].to_vec();
        p[21] = 99;
        assert!(WalRecord::decode(&p).is_err());
    }
}
