//! Read-only WAL tailing for replication: a [`WalCursor`] follows one
//! shard's segmented log *while a live appender grows it*, yielding
//! each fully-durable frame exactly once from a caller-chosen LSN.
//!
//! This is the primary-side half of WAL shipping (`fast-repl-v1`): the
//! repl listener owns one cursor per shard per follower connection and
//! pumps frames from the files the engine's [`ShardWal`] appenders are
//! writing — no engine hook, no extra channel, the log *is* the
//! replication stream.
//!
//! ## Live-tail safety
//!
//! An appender emits a frame as ONE sequential `write_all` of the
//! complete `len | crc | payload` buffer (CRC backfilled before the
//! write), so a reader that sees byte `k` of a frame knows bytes
//! `0..k` are final. That yields a crisp classification at the tail:
//!
//! - fewer bytes than a complete frame → **pending** (an in-flight
//!   append; retry later),
//! - a complete frame with an implausible length, a CRC mismatch, or
//!   an undecodable payload → **corruption** (hard error — shipping a
//!   bad frame would replicate the damage),
//! - a clean end-of-file with a NEWER segment present → the current
//!   segment is **sealed** (rotation happened); the cursor reports the
//!   boundary so the shipper can emit its segment digest, then moves
//!   on.
//!
//! A torn tail left by a crash never reaches a cursor: durable engine
//! start truncates it during recovery before any appender (and thus
//! any shipping) resumes.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context};

use crate::Result;

use super::segment::{list_segments, read_segment_header, SEGMENT_HEADER_LEN};
use super::wal::{WalRecord, MAX_PAYLOAD};

/// Smallest valid frame payload (the fixed fields with zero ops) —
/// mirrors the private `PAYLOAD_FIXED` in [`super::wal`]:
/// `rtype(1) + shard(4) + lsn(8) + commit_seq(8) + seal(1) + kind(1) +
/// nops(4)`.
const MIN_PAYLOAD: u32 = 27;

/// What one [`WalCursor::poll`] observed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CursorEvent {
    /// One durable frame at exactly the cursor's next LSN: the decoded
    /// record plus the raw frame bytes (`len | crc | payload`) as they
    /// sit on disk — ship the bytes, trust the record.
    Frame { record: WalRecord, frame: Vec<u8> },
    /// The segment holding everything up to `upto_lsn` is sealed
    /// (rotation happened); the next poll continues in the successor
    /// segment. Shippers emit their cumulative digest here.
    SegmentSealed { upto_lsn: u64 },
    /// Caught up with the appender — nothing durable beyond the
    /// cursor yet. Retry after a pause.
    Idle,
}

/// One open segment file the cursor is scanning.
struct OpenSeg {
    file: File,
    path: PathBuf,
    first_lsn: u64,
    /// Byte offset of the next unread frame (header included).
    offset: u64,
}

/// Read-only tailer over one shard's WAL from a starting LSN. Never
/// takes the directory's writer lock — it only reads files the
/// appender has already made durable.
pub struct WalCursor {
    dir: PathBuf,
    shard: usize,
    /// Next LSN to yield (frames below it are skipped on resume).
    next_lsn: u64,
    /// Highest LSN observed in the log so far (read or skipped) — the
    /// durable tail as this cursor knows it; heartbeats carry it.
    max_seen: u64,
    seg: Option<OpenSeg>,
    /// Path of the segment last reported sealed: re-choosing it means
    /// the successor segment is missing or starts beyond `next_lsn` —
    /// a log gap, not a wait state.
    sealed_path: Option<PathBuf>,
}

impl WalCursor {
    /// Cursor over `shard`'s log under `dir`, starting at `from_lsn`
    /// (use recovered watermark + 1 to resume; 1 to bootstrap).
    pub fn new(dir: &Path, shard: usize, from_lsn: u64) -> Result<WalCursor> {
        ensure!(from_lsn >= 1, "lsn space starts at 1");
        Ok(WalCursor {
            dir: dir.to_path_buf(),
            shard,
            next_lsn: from_lsn,
            max_seen: 0,
            seg: None,
            sealed_path: None,
        })
    }

    /// The LSN the next [`CursorEvent::Frame`] will carry.
    pub fn next_lsn(&self) -> u64 {
        self.next_lsn
    }

    /// Highest LSN this cursor has observed on disk (0 before the
    /// first poll touches data). Everything at or below it is durable.
    pub fn tail_seen(&self) -> u64 {
        self.max_seen
    }

    /// Choose and open the segment that should contain `next_lsn`.
    /// `Ok(false)` = no segment exists yet (fresh shard) — idle.
    fn open_current(&mut self) -> Result<bool> {
        let segs = list_segments(&self.dir, self.shard)?;
        let Some(info) = segs.iter().rev().find(|s| s.first_lsn <= self.next_lsn) else {
            if let Some(oldest) = segs.first() {
                bail!(
                    "shard {}: lsn {} predates the oldest segment (first lsn {}) — \
                     the primary compacted past this cursor; re-seed the follower \
                     from a fresh copy of the primary's state",
                    self.shard,
                    self.next_lsn,
                    oldest.first_lsn
                );
            }
            return Ok(false);
        };
        if self.sealed_path.as_deref() == Some(info.path.as_path()) {
            bail!(
                "shard {}: segment {} is sealed at lsn {} but no successor segment \
                 covers it — log gap",
                self.shard,
                info.path.display(),
                self.next_lsn - 1
            );
        }
        let mut file = File::open(&info.path)
            .with_context(|| format!("opening segment {}", info.path.display()))?;
        let claimed = read_segment_header(&mut file, &info.path)?;
        ensure!(
            claimed as usize == self.shard,
            "{}: segment claims shard {claimed}, cursor follows shard {}",
            info.path.display(),
            self.shard
        );
        self.seg = Some(OpenSeg {
            file,
            path: info.path.clone(),
            first_lsn: info.first_lsn,
            offset: SEGMENT_HEADER_LEN,
        });
        Ok(true)
    }

    /// Advance by at most one event. Errors are permanent (corruption,
    /// compaction gap); [`CursorEvent::Idle`] is the retryable state.
    pub fn poll(&mut self) -> Result<CursorEvent> {
        loop {
            if self.seg.is_none() && !self.open_current()? {
                return Ok(CursorEvent::Idle);
            }
            let seg = self.seg.as_mut().expect("opened above");
            let flen = seg
                .file
                .metadata()
                .with_context(|| format!("statting {}", seg.path.display()))?
                .len();
            match read_frame_at(&mut seg.file, &seg.path, seg.offset, flen)? {
                Some((record, frame)) => {
                    seg.offset += frame.len() as u64;
                    ensure!(
                        record.shard as usize == self.shard,
                        "{}: record claims shard {}, cursor follows shard {}",
                        seg.path.display(),
                        record.shard,
                        self.shard
                    );
                    self.max_seen = self.max_seen.max(record.lsn);
                    if record.lsn < self.next_lsn {
                        continue; // resume skip: already shipped/applied
                    }
                    ensure!(
                        record.lsn == self.next_lsn,
                        "shard {}: {} jumps to lsn {} (expected {}) — log gap",
                        self.shard,
                        seg.path.display(),
                        record.lsn,
                        self.next_lsn
                    );
                    self.next_lsn += 1;
                    return Ok(CursorEvent::Frame { record, frame });
                }
                None => {
                    // No complete frame at the tail. Sealed or pending?
                    let newer = list_segments(&self.dir, self.shard)?
                        .iter()
                        .any(|s| s.first_lsn > seg.first_lsn);
                    if !newer {
                        return Ok(CursorEvent::Idle);
                    }
                    // Rotation happened, so this segment is immutable:
                    // it must end exactly at a frame boundary.
                    ensure!(
                        seg.offset == flen,
                        "shard {}: sealed segment {} ends mid-frame at byte {} of {}",
                        self.shard,
                        seg.path.display(),
                        seg.offset,
                        flen
                    );
                    let upto_lsn = self.next_lsn - 1;
                    self.sealed_path = Some(seg.path.clone());
                    self.seg = None;
                    return Ok(CursorEvent::SegmentSealed { upto_lsn });
                }
            }
        }
    }
}

/// Read the frame at `offset`, given the file currently holds `flen`
/// bytes. `Ok(None)` = the frame is not fully durable yet (pending
/// append). `Err` = the durable bytes are wrong (corruption).
fn read_frame_at(
    file: &mut File,
    path: &Path,
    offset: u64,
    flen: u64,
) -> Result<Option<(WalRecord, Vec<u8>)>> {
    if flen < offset + 8 {
        return Ok(None); // frame header not fully durable yet
    }
    file.seek(SeekFrom::Start(offset))
        .with_context(|| format!("seeking {}", path.display()))?;
    let mut head = [0u8; 8];
    file.read_exact(&mut head)
        .with_context(|| format!("reading frame header in {}", path.display()))?;
    let len = u32::from_le_bytes(head[..4].try_into().expect("4 bytes"));
    let crc = u32::from_le_bytes(head[4..].try_into().expect("4 bytes"));
    // The header bytes are final once visible (appends are sequential),
    // so an implausible length is corruption, not an in-flight write.
    ensure!(
        (MIN_PAYLOAD..=MAX_PAYLOAD).contains(&len),
        "{}: implausible frame length {len} at byte {offset}",
        path.display()
    );
    if flen < offset + 8 + len as u64 {
        return Ok(None); // payload still landing
    }
    let mut payload = vec![0u8; len as usize];
    file.read_exact(&mut payload)
        .with_context(|| format!("reading frame payload in {}", path.display()))?;
    ensure!(
        crate::util::crc32::crc32(&payload) == crc,
        "{}: frame CRC mismatch at byte {offset}",
        path.display()
    );
    let record = WalRecord::decode(&payload)
        .with_context(|| format!("{}: undecodable frame at byte {offset}", path.display()))?;
    let mut frame = Vec::with_capacity(8 + payload.len());
    frame.extend_from_slice(&head);
    frame.extend_from_slice(&payload);
    Ok(Some((record, frame)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::BatchKind;
    use crate::durability::segment::{encode_segment_header, segment_path, shard_dir};
    use crate::durability::wal::WalPayload;
    use std::io::Write;

    fn tmpdir(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let d = std::env::temp_dir().join(format!(
            "fast-cursor-{tag}-{}-{nanos}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn batch_rec(lsn: u64, seq: u64, ops: Vec<(u32, u32)>) -> WalRecord {
        WalRecord {
            shard: 0,
            lsn,
            commit_seq: seq,
            payload: WalPayload::Batch {
                seal_reason: crate::coordinator::SealReason::Forced,
                kind: BatchKind::Add,
                ops,
            },
        }
    }

    fn new_segment(dir: &Path, first_lsn: u64) -> std::fs::File {
        std::fs::create_dir_all(shard_dir(dir, 0)).unwrap();
        let mut f = std::fs::File::create(segment_path(dir, 0, first_lsn)).unwrap();
        f.write_all(&encode_segment_header(0)).unwrap();
        f
    }

    fn append(f: &mut std::fs::File, rec: &WalRecord) -> Vec<u8> {
        let mut buf = Vec::new();
        rec.encode_into(&mut buf);
        f.write_all(&buf).unwrap();
        buf
    }

    #[test]
    fn tails_a_growing_segment_and_ships_exact_bytes() {
        let d = tmpdir("tail");
        let mut cur = WalCursor::new(&d, 0, 1).unwrap();
        // Fresh shard: no segments at all is idle, not an error.
        assert_eq!(cur.poll().unwrap(), CursorEvent::Idle);
        let mut f = new_segment(&d, 1);
        assert_eq!(cur.poll().unwrap(), CursorEvent::Idle);
        let b1 = append(&mut f, &batch_rec(1, 1, vec![(3, 7)]));
        let b2 = append(&mut f, &batch_rec(2, 2, vec![(0, 1), (5, 2)]));
        match cur.poll().unwrap() {
            CursorEvent::Frame { record, frame } => {
                assert_eq!(record.lsn, 1);
                assert_eq!(frame, b1, "shipped bytes must be the on-disk bytes");
            }
            other => panic!("expected frame, got {other:?}"),
        }
        match cur.poll().unwrap() {
            CursorEvent::Frame { record, frame } => {
                assert_eq!(record.lsn, 2);
                assert_eq!(frame, b2);
            }
            other => panic!("expected frame, got {other:?}"),
        }
        assert_eq!(cur.poll().unwrap(), CursorEvent::Idle);
        assert_eq!(cur.tail_seen(), 2);
        // More data arrives: the same cursor picks it up.
        append(&mut f, &batch_rec(3, 3, vec![(1, 1)]));
        assert!(matches!(
            cur.poll().unwrap(),
            CursorEvent::Frame { record: WalRecord { lsn: 3, .. }, .. }
        ));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn partial_tail_frame_is_pending_not_corrupt() {
        let d = tmpdir("partial");
        let mut f = new_segment(&d, 1);
        let full = {
            let mut buf = Vec::new();
            batch_rec(1, 1, vec![(2, 9)]).encode_into(&mut buf);
            buf
        };
        // Write only a prefix (mid-append snapshot).
        f.write_all(&full[..full.len() - 3]).unwrap();
        let mut cur = WalCursor::new(&d, 0, 1).unwrap();
        assert_eq!(cur.poll().unwrap(), CursorEvent::Idle);
        // The rest lands: now it ships.
        f.write_all(&full[full.len() - 3..]).unwrap();
        assert!(matches!(cur.poll().unwrap(), CursorEvent::Frame { .. }));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn resume_skips_below_start_and_detects_rotation() {
        let d = tmpdir("rotate");
        let mut f1 = new_segment(&d, 1);
        for lsn in 1..=3u64 {
            append(&mut f1, &batch_rec(lsn, lsn, vec![(0, lsn as u32)]));
        }
        let mut f2 = new_segment(&d, 4);
        append(&mut f2, &batch_rec(4, 4, vec![(1, 1)]));
        // Resume from lsn 3: skips 1-2, ships 3, reports the seal,
        // then continues into the successor segment.
        let mut cur = WalCursor::new(&d, 0, 3).unwrap();
        assert!(matches!(
            cur.poll().unwrap(),
            CursorEvent::Frame { record: WalRecord { lsn: 3, .. }, .. }
        ));
        assert_eq!(cur.poll().unwrap(), CursorEvent::SegmentSealed { upto_lsn: 3 });
        assert!(matches!(
            cur.poll().unwrap(),
            CursorEvent::Frame { record: WalRecord { lsn: 4, .. }, .. }
        ));
        assert_eq!(cur.poll().unwrap(), CursorEvent::Idle);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corruption_is_a_permanent_error() {
        let d = tmpdir("corrupt");
        let mut f = new_segment(&d, 1);
        append(&mut f, &batch_rec(1, 1, vec![(0, 5)]));
        append(&mut f, &batch_rec(2, 2, vec![(1, 6)]));
        drop(f);
        // Flip a payload byte of the FIRST frame: its CRC no longer
        // matches, and the bytes are fully durable — corruption.
        let path = segment_path(&d, 0, 1);
        let mut bytes = std::fs::read(&path).unwrap();
        let at = SEGMENT_HEADER_LEN as usize + 8 + 5;
        bytes[at] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let mut cur = WalCursor::new(&d, 0, 1).unwrap();
        let err = cur.poll().unwrap_err().to_string();
        assert!(err.contains("CRC"), "unexpected error: {err}");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn compacted_history_is_an_actionable_error() {
        let d = tmpdir("gap");
        let mut f = new_segment(&d, 10);
        append(&mut f, &batch_rec(10, 10, vec![(0, 1)]));
        // Asking for lsn 1 when the log starts at 10 cannot be served.
        let mut cur = WalCursor::new(&d, 0, 1).unwrap();
        let err = cur.poll().unwrap_err().to_string();
        assert!(err.contains("re-seed"), "unexpected error: {err}");
        let _ = std::fs::remove_dir_all(&d);
    }
}
