//! Crash recovery: rebuild the logical row state from the newest valid
//! snapshot plus each shard's WAL tail, repair torn tails, compact
//! covered segments, and convert a WAL into a `fast-trace-v1` trace so
//! `fast trace replay --digest-only` can independently check any
//! recovered state.
//!
//! ## Invariants
//!
//! - **Prefix consistency.** Recovery applies, per shard, exactly the
//!   records of a prefix of what was appended: the scan stops at the
//!   first bad frame, and (in repair mode) truncates the file there
//!   and drops any later segments of that shard. No record after a gap
//!   is ever applied.
//! - **Dedup.** Records at or below the snapshot watermark are skipped
//!   twice over: by LSN (which orders writes too) and, for batch
//!   records, by `commit_seq` — replaying a WAL tail over a snapshot
//!   can never double-apply a commit.
//! - **Monotonicity.** LSNs must strictly increase within a shard's
//!   scan; a non-monotone record is treated as corruption at that
//!   offset, not applied.
//! - **Digest verification.** A snapshot's stored digest is recomputed
//!   on load (see `snapshot.rs`); [`RecoverReport::digest`] is the
//!   digest of the *recovered* state, comparable against
//!   `fast trace replay --digest-only` of the exported trace.
//! - **Idempotence.** Recovering an already-recovered directory (even
//!   twice in a row) yields byte-identical state and watermarks.

use std::fs::{self, OpenOptions};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context};

use crate::apps::trace::{state_digest, Trace};
use crate::coordinator::request::{BatchKind, UpdateOp, UpdateRequest};
use crate::util::bits;
use crate::Result;

use super::segment::{self, Manifest, SEGMENT_HEADER_LEN};
use super::snapshot::{self, ShardMark, Snapshot};
use super::wal::{SegmentReader, WalPayload, WalRecord};
use super::DurabilityConfig;

/// One repaired (or repair-needing) torn tail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TornNote {
    pub shard: usize,
    pub segment: PathBuf,
    /// Byte offset of the good prefix (the truncation point).
    pub offset: u64,
    pub reason: String,
    /// Later segments of the shard made unreachable by the bad frame
    /// (0 when the tear is in the final segment — the normal crash
    /// artifact).
    pub dropped_segments: usize,
}

/// Outcome of a recovery pass.
#[derive(Debug, Clone)]
pub struct RecoverReport {
    pub rows: usize,
    pub q: usize,
    pub shards: usize,
    /// Recovered logical row state.
    pub state: Vec<u32>,
    /// Post-tail-replay watermark per shard.
    pub per_shard: Vec<ShardMark>,
    /// FNV-1a digest of `state` (the serve/trace digest function).
    pub digest: u64,
    /// Snapshot file the recovery started from, if any.
    pub snapshot: Option<PathBuf>,
    /// Segments scanned across all shards.
    pub segments: usize,
    /// WAL records applied on top of the snapshot.
    pub records_replayed: u64,
    /// Torn tails found (and, in repair mode, fixed).
    pub torn: Vec<TornNote>,
}

impl RecoverReport {
    /// Slice the recovered state down to one shard's local rows
    /// (`local_row -> state[(local << log2(shards)) | shard]`).
    pub fn shard_state(&self, shard: usize) -> Vec<u32> {
        let bits = self.shards.trailing_zeros();
        let shard_rows = self.rows / self.shards;
        (0..shard_rows).map(|local| self.state[(local << bits) | shard]).collect()
    }
}

/// What a recovery pass is allowed to do to the files it scans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Repair {
    /// Report damage, touch nothing (`fast wal inspect|verify|export`).
    ReadOnly,
    /// Truncate a torn FINAL-segment tail (the normal crash artifact),
    /// but REFUSE mid-log corruption that makes later segments
    /// unreachable — repairing that silently would discard fsynced,
    /// acknowledged commits. Engine startup and compaction run this.
    TailOnly,
    /// Truncate at the first bad frame wherever it is and delete the
    /// unreachable segments — explicit data-loss acceptance
    /// (`fast wal repair`).
    Force,
}

/// Read-only recovery of an existing WAL directory (shape comes from
/// its manifest). Torn tails are reported, not repaired; the returned
/// state is the consistent prefix either way.
pub fn recover(dir: &Path) -> Result<RecoverReport> {
    scan(dir, Repair::ReadOnly, &mut |_, _| {}).map(|(rep, _)| rep)
}

/// Recovery with tail repair: a torn final-segment tail is truncated
/// at the last good frame so a subsequent appender can extend the log
/// in place. Corruption that strands later segments is an ERROR (run
/// [`recover_force`] / `fast wal repair` to accept the loss). This is
/// what a durable engine runs at startup.
pub fn recover_repair(dir: &Path) -> Result<RecoverReport> {
    scan(dir, Repair::TailOnly, &mut |_, _| {}).map(|(rep, _)| rep)
}

/// Destructive repair: truncate at the first bad frame wherever it
/// sits and delete the segments it strands. Only for explicit
/// operator use — this is how acknowledged commits get discarded.
pub fn recover_force(dir: &Path) -> Result<RecoverReport> {
    scan(dir, Repair::Force, &mut |_, _| {}).map(|(rep, _)| rep)
}

/// Engine-startup entry point: initialize the directory on first use
/// (manifest + shard dirs), validate the shape against the engine
/// config on reuse, then recover with repair.
pub fn recover_or_init(
    cfg: &DurabilityConfig,
    rows: usize,
    q: usize,
    shards: usize,
) -> Result<RecoverReport> {
    fs::create_dir_all(&cfg.dir)
        .with_context(|| format!("creating WAL dir {}", cfg.dir.display()))?;
    if Manifest::exists(&cfg.dir) {
        let m = Manifest::load(&cfg.dir)?;
        ensure!(
            m == (Manifest { rows, q, shards }),
            "WAL dir {} belongs to a {}x{} engine with {} shard(s); \
             this engine is {rows}x{q} with {shards} shard(s) — refusing to mix",
            cfg.dir.display(),
            m.rows,
            m.q,
            m.shards
        );
    } else {
        Manifest { rows, q, shards }.write_atomic(&cfg.dir)?;
    }
    for shard in 0..shards {
        fs::create_dir_all(segment::shard_dir(&cfg.dir, shard))?;
    }
    recover_repair(&cfg.dir)
}

/// The shared scan core: snapshot + per-shard tail replay, with every
/// applied record also handed to `sink` (export collects them;
/// recovery ignores them). Returns the loaded snapshot alongside the
/// report so callers that need the pre-tail base state (export) don't
/// re-read and re-verify the file.
fn scan(
    dir: &Path,
    repair: Repair,
    sink: &mut dyn FnMut(usize, &WalRecord),
) -> Result<(RecoverReport, Option<Snapshot>)> {
    let m = Manifest::load(dir)?;
    let shard_bits = m.shards.trailing_zeros();
    let shard_rows = m.rows / m.shards;
    let mask = bits::mask(m.q);

    let (snapshot_path, base, watermarks) = match snapshot::load_newest(dir)? {
        Some((path, snap)) => {
            ensure!(
                snap.rows == m.rows && snap.q == m.q && snap.shards == m.shards,
                "snapshot {} shape {}x{}/{} disagrees with manifest {}x{}/{}",
                path.display(),
                snap.rows,
                snap.q,
                snap.shards,
                m.rows,
                m.q,
                m.shards
            );
            let marks = snap.per_shard.clone();
            (Some(path), Some(snap), marks)
        }
        None => (None, None, vec![ShardMark::default(); m.shards]),
    };
    let mut state = base
        .as_ref()
        .map(|s| s.state.clone())
        .unwrap_or_else(|| vec![0u32; m.rows]);

    let mut per_shard = watermarks.clone();
    let mut torn = Vec::new();
    let mut segments = 0usize;
    let mut records_replayed = 0u64;

    for shard in 0..m.shards {
        let wm = watermarks[shard];
        let segs = segment::list_segments(dir, shard)?;
        segments += segs.len();
        // Strict-monotonicity tracker over the whole scan (skipped
        // records count too — they still occupy LSNs).
        let mut scan_lsn = 0u64;
        let mut stop: Option<(usize, u64, String)> = None; // (seg idx, offset, why)

        'segs: for (i, seg) in segs.iter().enumerate() {
            let mut rd = match SegmentReader::open(&seg.path, shard) {
                Ok(rd) => rd,
                Err(e) => {
                    // Headerless / foreign file: torn at byte 0.
                    stop = Some((i, 0, format!("{e:#}")));
                    break 'segs;
                }
            };
            loop {
                // Good-prefix length BEFORE this frame: the truncation
                // point if the frame turns out bad (the reader's own
                // offset only advances past frames it accepted).
                let frame_start = rd.offset();
                let Some(rec) = rd.next_record() else { break };
                if rec.lsn <= scan_lsn {
                    stop = Some((
                        i,
                        frame_start,
                        format!("non-monotone lsn {} after {}", rec.lsn, scan_lsn),
                    ));
                    break 'segs;
                }
                scan_lsn = rec.lsn;
                // A CRC-valid record addressing rows this shard does
                // not own is corruption, not data — stop here exactly
                // like a bad frame (never silently drop logged ops).
                if let Some(row) = out_of_range_row(&rec, shard_rows) {
                    stop = Some((
                        i,
                        frame_start,
                        format!(
                            "record lsn {} addresses local row {row} beyond the \
                             shard's {shard_rows} rows",
                            rec.lsn
                        ),
                    ));
                    break 'segs;
                }
                // Dedup against the snapshot watermark: by LSN (orders
                // writes) and, for batches, by commit_seq as well. The
                // LSN watermark advances over every record seen, so a
                // later appender can never reuse a logged LSN.
                if rec.lsn <= wm.lsn {
                    continue;
                }
                per_shard[shard].lsn = rec.lsn;
                if let WalPayload::Batch { .. } = rec.payload {
                    if rec.commit_seq <= wm.commit_seq {
                        continue;
                    }
                }
                apply_record(&mut state, &rec, shard, shard_bits, mask, m.q);
                sink(shard, &rec);
                if let WalPayload::Batch { .. } = rec.payload {
                    per_shard[shard].commit_seq = rec.commit_seq;
                }
                records_replayed += 1;
            }
            if let Some(t) = rd.torn() {
                stop = Some((i, t.offset, t.reason.clone()));
                break 'segs;
            }
        }

        if let Some((seg_idx, offset, reason)) = stop {
            let dropped = segs.len() - seg_idx - 1;
            match repair {
                Repair::ReadOnly => {}
                // Repairing past a mid-log tear would delete segments
                // full of fsynced, acknowledged commits — refuse
                // unless the operator explicitly forces it.
                Repair::TailOnly if dropped > 0 => bail!(
                    "shard {shard}: bad frame in {} at byte {offset} ({reason}) makes \
                     {dropped} later segment(s) unreachable; refusing to repair past \
                     acknowledged commits — run `fast wal repair --dir …` to accept \
                     the data loss",
                    segs[seg_idx].path.display()
                ),
                Repair::TailOnly | Repair::Force => {
                    repair_tail(&segs[seg_idx].path, offset)?;
                    for later in &segs[seg_idx + 1..] {
                        fs::remove_file(&later.path).with_context(|| {
                            format!("removing unreachable segment {}", later.path.display())
                        })?;
                    }
                }
            }
            torn.push(TornNote {
                shard,
                segment: segs[seg_idx].path.clone(),
                offset,
                reason,
                dropped_segments: dropped,
            });
        }
    }

    let digest = state_digest(&state);
    let report = RecoverReport {
        rows: m.rows,
        q: m.q,
        shards: m.shards,
        state,
        per_shard,
        digest,
        snapshot: snapshot_path,
        segments,
        records_replayed,
        torn,
    };
    Ok((report, base))
}

/// The first shard-local row a record addresses that is outside the
/// shard's row space, if any.
fn out_of_range_row(rec: &WalRecord, shard_rows: usize) -> Option<u32> {
    match &rec.payload {
        WalPayload::Batch { ops, .. } => ops
            .iter()
            .map(|&(row, _)| row)
            .find(|&row| row as usize >= shard_rows),
        WalPayload::Write { row, .. } => (*row as usize >= shard_rows).then_some(*row),
    }
}

fn apply_record(
    state: &mut [u32],
    rec: &WalRecord,
    shard: usize,
    shard_bits: u32,
    mask: u32,
    q: usize,
) {
    let logical = |local: u32| ((local as usize) << shard_bits) | shard;
    match &rec.payload {
        WalPayload::Batch { kind, ops, .. } => {
            for &(local, operand) in ops {
                let row = logical(local);
                if row < state.len() {
                    state[row] = kind.coalesce(state[row], operand, q);
                }
            }
        }
        WalPayload::Write { row, value } => {
            let row = logical(*row);
            if row < state.len() {
                state[row] = value & mask;
            }
        }
    }
}

/// Truncate a torn segment at its last good frame. A good prefix
/// shorter than the segment header means the file never held a valid
/// record — remove it entirely.
fn repair_tail(path: &Path, offset: u64) -> Result<()> {
    if offset < SEGMENT_HEADER_LEN {
        fs::remove_file(path)
            .with_context(|| format!("removing headerless segment {}", path.display()))?;
        return Ok(());
    }
    let f = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("opening {} for truncation", path.display()))?;
    f.set_len(offset)
        .with_context(|| format!("truncating {} to {offset} bytes", path.display()))?;
    f.sync_data().context("fsyncing truncated segment")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Compaction
// ---------------------------------------------------------------------------

/// Outcome of a compaction pass.
#[derive(Debug, Clone)]
pub struct CompactReport {
    pub snapshot: PathBuf,
    pub digest: u64,
    pub segments_removed: usize,
    pub bytes_reclaimed: u64,
    pub snapshots_removed: usize,
}

/// Compact a WAL directory: recover (with repair), write a full-state
/// snapshot at the recovered watermarks, then delete every segment the
/// snapshot covers (all of them — the scan replayed everything) and
/// every older snapshot. Offline only: do not run against a directory
/// a live `fast serve` is appending to.
pub fn compact(dir: &Path) -> Result<CompactReport> {
    let rep = recover_repair(dir)?;
    let snap = Snapshot {
        rows: rep.rows,
        q: rep.q,
        shards: rep.shards,
        per_shard: rep.per_shard.clone(),
        state: rep.state.clone(),
    };
    let snapshot_path = snap.write_atomic(dir)?;

    let mut segments_removed = 0usize;
    let mut bytes_reclaimed = 0u64;
    for shard in 0..rep.shards {
        for seg in segment::list_segments(dir, shard)? {
            bytes_reclaimed += seg.bytes;
            fs::remove_file(&seg.path)
                .with_context(|| format!("removing covered segment {}", seg.path.display()))?;
            segments_removed += 1;
        }
    }
    let mut snapshots_removed = 0usize;
    for (_, path) in snapshot::list_snapshots(dir)? {
        if path != snapshot_path {
            bytes_reclaimed += fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            fs::remove_file(&path)
                .with_context(|| format!("removing superseded snapshot {}", path.display()))?;
            snapshots_removed += 1;
        }
    }
    Ok(CompactReport {
        snapshot: snapshot_path,
        digest: rep.digest,
        segments_removed,
        bytes_reclaimed,
        snapshots_removed,
    })
}

// ---------------------------------------------------------------------------
// Trace interop
// ---------------------------------------------------------------------------

fn kind_op(kind: BatchKind) -> UpdateOp {
    match kind {
        BatchKind::Add => UpdateOp::Add,
        BatchKind::And => UpdateOp::And,
        BatchKind::Or => UpdateOp::Or,
        BatchKind::Xor => UpdateOp::Xor,
    }
}

/// Convert a WAL directory into a `fast-trace-v1` [`Trace`] whose
/// replay reproduces the recovered state bit for bit: the snapshot
/// state becomes absolute writes, each shard's tail records become
/// update/write events in log order (shards own disjoint rows, so
/// per-shard order is the only order that matters), and a final flush
/// closes the stream. `fast trace replay --digest-only` of the export
/// is an independent check of any recovered state.
pub fn export_trace(dir: &Path, name: &str) -> Result<Trace> {
    let m = Manifest::load(dir)?;
    let shard_bits = m.shards.trailing_zeros();

    // Collect the tail records per shard (read-only scan; the scan
    // hands back the verified snapshot it loaded, so the base state
    // is not read or checked twice).
    let mut tails: Vec<Vec<WalRecord>> = vec![Vec::new(); m.shards];
    let (rep, base) = scan(dir, Repair::ReadOnly, &mut |shard, rec| {
        tails[shard].push(rec.clone())
    })?;

    let mut trace = Trace::new(name, m.rows, m.q, 0);
    // Snapshot base state first (zeros need no event).
    if let Some(snap) = &base {
        for (row, &v) in snap.state.iter().enumerate() {
            if v != 0 {
                trace.push_write(row, v);
            }
        }
    }
    let mask = bits::mask(m.q);
    for (shard, records) in tails.iter().enumerate() {
        let logical = |local: u32| ((local as usize) << shard_bits) | shard;
        for rec in records {
            match &rec.payload {
                WalPayload::Batch { kind, ops, .. } => {
                    let op = kind_op(*kind);
                    for &(local, operand) in ops {
                        let row = logical(local);
                        ensure!(
                            row < m.rows && operand <= mask,
                            "shard {shard} lsn {}: op (row {row}, operand {operand:#x}) \
                             out of range for {}x{}",
                            rec.lsn,
                            m.rows,
                            m.q
                        );
                        trace.push_update(UpdateRequest { row, op, operand });
                    }
                }
                WalPayload::Write { row, value } => {
                    let row = logical(*row);
                    ensure!(
                        row < m.rows && *value <= mask,
                        "shard {shard} lsn {}: write (row {row}, value {value:#x}) \
                         out of range for {}x{}",
                        rec.lsn,
                        m.rows,
                        m.q
                    );
                    trace.push_write(row, *value);
                }
            }
        }
    }
    trace.push_flush();

    // The conversion is only correct if it reproduces the recovered
    // state — check against the host-semantics oracle before handing
    // the trace out.
    let folded = trace.reference_state();
    if state_digest(&folded) != rep.digest {
        bail!(
            "WAL→trace conversion diverged from the recovered state \
             ({:016x} vs {:016x}) — this is a bug",
            state_digest(&folded),
            rep.digest
        );
    }
    Ok(trace)
}
