//! WAL segment layout: per-shard directories of size-rotated segment
//! files plus the shape manifest (`wal.json`) that pins the row space
//! a WAL directory belongs to.
//!
//! ## On-disk layout
//!
//! ```text
//! <wal_dir>/
//!   wal.json                    # shape manifest {rows, q, shards}
//!   snap-XXXXXXXXXXXXXXXX.fastsnap   # full-state snapshots (see snapshot.rs)
//!   shard-000/
//!     seg-XXXXXXXXXXXXXXXX.wal # segments, named by their FIRST lsn (hex)
//!     seg-….wal
//!   shard-001/…
//! ```
//!
//! Naming segments by first LSN makes the lexicographic directory
//! order the log order (sneldb names its WAL files the same way), and
//! makes "is this segment fully covered by a snapshot at lsn L?"
//! answerable from the *next* segment's name alone. Every segment
//! starts with a 16-byte header (`magic | version | shard`) so a
//! misplaced or foreign file is rejected before any frame is parsed.

use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context};

use crate::util::json::Json;
use crate::Result;

/// Advisory writer lock file at the WAL-directory root.
pub const LOCK_FILE: &str = "wal.lock";

/// Advisory single-writer lock on a WAL directory. Two appenders on
/// one directory interleave frames with duplicate LSNs — which a later
/// recovery reads as corruption and truncates, silently discarding
/// acknowledged commits — so every *mutating* entry point (a durable
/// engine start, `fast wal compact|repair`) takes this lock first.
///
/// Implementation: an OS advisory file lock (`File::try_lock`, std
/// since Rust 1.89) on `wal.lock`. The kernel releases it when the
/// holding process dies — SIGKILL included — so there is no stale-lock
/// state, no PID probing, and no read-then-delete reclaim race. The
/// lock file itself is never removed (unlinking a locked path is the
/// classic way to let a third process lock a fresh file under the same
/// name); a leftover `wal.lock` is inert.
#[derive(Debug)]
pub struct DirLock {
    /// Held open for the lock's lifetime; closing releases the lock.
    _file: fs::File,
}

impl DirLock {
    pub fn acquire(dir: &Path) -> Result<DirLock> {
        let path = dir.join(LOCK_FILE);
        let file = fs::OpenOptions::new()
            .create(true)
            .truncate(false)
            .write(true)
            .open(&path)
            .with_context(|| format!("opening WAL lock {}", path.display()))?;
        match file.try_lock() {
            Ok(()) => {
                // Stamp the holder for humans inspecting the dir; the
                // flock, not the content, is the actual exclusion.
                let _ = file.set_len(0);
                let _ = std::io::Write::write_all(
                    &mut &file,
                    std::process::id().to_string().as_bytes(),
                );
                Ok(DirLock { _file: file })
            }
            Err(std::fs::TryLockError::WouldBlock) => bail!(
                "WAL dir {} is locked by another live process ({}); a second \
                 writer would corrupt the log — stop it first",
                dir.display(),
                path.display()
            ),
            Err(std::fs::TryLockError::Error(e)) => {
                Err(e).with_context(|| format!("locking WAL dir {}", dir.display()))
            }
        }
    }
}

/// Segment file magic (8 bytes) — bump `SEGMENT_VERSION` on breaking
/// frame-format changes instead of editing this.
pub const SEGMENT_MAGIC: &[u8; 8] = b"FASTWAL1";
/// Frame-format version carried in every segment header.
pub const SEGMENT_VERSION: u32 = 1;
/// Bytes of segment header before the first frame: magic(8) +
/// version(4) + shard(4).
pub const SEGMENT_HEADER_LEN: u64 = 16;

/// Manifest file name at the WAL-directory root.
pub const MANIFEST_FILE: &str = "wal.json";
/// Format tag inside the manifest; bump on breaking layout changes.
pub const MANIFEST_FORMAT: &str = "fast-wal-v1";

/// The shape manifest: which logical row space this WAL directory
/// logs. Recovery and the appenders refuse to touch a directory whose
/// manifest disagrees with the engine config — silently mixing WALs of
/// different shapes is how state gets corrupted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Manifest {
    pub rows: usize,
    pub q: usize,
    pub shards: usize,
}

impl Manifest {
    /// Canonical one-line JSON rendering (fixed key order).
    fn to_json(self) -> String {
        format!(
            "{{\"wal\":\"{}\",\"rows\":{},\"q\":{},\"shards\":{}}}\n",
            MANIFEST_FORMAT, self.rows, self.q, self.shards
        )
    }

    /// Write the manifest atomically (temp file + rename).
    pub fn write_atomic(self, dir: &Path) -> Result<()> {
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        let fin = dir.join(MANIFEST_FILE);
        fs::write(&tmp, self.to_json())
            .with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &fin)
            .with_context(|| format!("renaming {} into place", fin.display()))?;
        Ok(())
    }

    /// Load and validate the manifest of an existing WAL directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join(MANIFEST_FILE);
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading WAL manifest {}", path.display()))?;
        let j = Json::parse(text.trim()).context("parsing WAL manifest")?;
        ensure!(
            j.get("wal").and_then(Json::as_str) == Some(MANIFEST_FORMAT),
            "{} is not a {MANIFEST_FORMAT} manifest",
            path.display()
        );
        let field = |key: &str| {
            j.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest field {key:?} missing or not an integer"))
        };
        let m = Manifest { rows: field("rows")?, q: field("q")?, shards: field("shards")? };
        ensure!(m.rows >= 1, "manifest rows must be >= 1");
        ensure!((1..=32).contains(&m.q), "manifest q {} out of range 1..=32", m.q);
        ensure!(
            m.shards >= 1 && m.shards.is_power_of_two(),
            "manifest shards {} must be a positive power of two",
            m.shards
        );
        ensure!(
            m.rows % m.shards == 0,
            "manifest rows {} not divisible by shards {}",
            m.rows,
            m.shards
        );
        Ok(m)
    }

    /// Does a manifest exist in `dir`?
    pub fn exists(dir: &Path) -> bool {
        dir.join(MANIFEST_FILE).is_file()
    }
}

/// Directory holding one shard's segments.
pub fn shard_dir(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("shard-{shard:03}"))
}

/// Path of the segment whose first record has `first_lsn`.
pub fn segment_path(dir: &Path, shard: usize, first_lsn: u64) -> PathBuf {
    shard_dir(dir, shard).join(format!("seg-{first_lsn:016x}.wal"))
}

/// One discovered segment of a shard's log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentInfo {
    pub path: PathBuf,
    /// First LSN the segment holds (parsed from the file name).
    pub first_lsn: u64,
    /// File size in bytes (header included).
    pub bytes: u64,
}

/// List a shard's segments in log order. Files that don't match the
/// `seg-<16 hex>.wal` pattern are ignored (a crashed rename can leave
/// `.tmp` debris behind).
pub fn list_segments(dir: &Path, shard: usize) -> Result<Vec<SegmentInfo>> {
    let sdir = shard_dir(dir, shard);
    let mut out = Vec::new();
    if !sdir.is_dir() {
        return Ok(out);
    }
    for entry in fs::read_dir(&sdir).with_context(|| format!("listing {}", sdir.display()))? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(hex) = name.strip_prefix("seg-").and_then(|s| s.strip_suffix(".wal")) else {
            continue;
        };
        let Ok(first_lsn) = u64::from_str_radix(hex, 16) else {
            continue;
        };
        let bytes = entry.metadata()?.len();
        out.push(SegmentInfo { path: entry.path(), first_lsn, bytes });
    }
    out.sort_by_key(|s| s.first_lsn);
    Ok(out)
}

/// Encode the 16-byte segment header.
pub fn encode_segment_header(shard: usize) -> [u8; SEGMENT_HEADER_LEN as usize] {
    let mut h = [0u8; SEGMENT_HEADER_LEN as usize];
    h[..8].copy_from_slice(SEGMENT_MAGIC);
    h[8..12].copy_from_slice(&SEGMENT_VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&(shard as u32).to_le_bytes());
    h
}

/// Read and validate a segment header, returning the shard it claims
/// to belong to.
pub fn read_segment_header(r: &mut impl Read, path: &Path) -> Result<u32> {
    let mut h = [0u8; SEGMENT_HEADER_LEN as usize];
    r.read_exact(&mut h)
        .with_context(|| format!("{}: segment header truncated", path.display()))?;
    ensure!(
        &h[..8] == SEGMENT_MAGIC,
        "{}: not a FAST WAL segment (bad magic)",
        path.display()
    );
    let version = u32::from_le_bytes(h[8..12].try_into().expect("4 bytes"));
    if version != SEGMENT_VERSION {
        bail!(
            "{}: unsupported segment version {version} (this build speaks {SEGMENT_VERSION})",
            path.display()
        );
    }
    Ok(u32::from_le_bytes(h[12..16].try_into().expect("4 bytes")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let d = std::env::temp_dir().join(format!(
            "fast-seg-{tag}-{}-{nanos}",
            std::process::id()
        ));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn manifest_round_trips_and_validates() {
        let d = tmpdir("manifest");
        let m = Manifest { rows: 256, q: 8, shards: 4 };
        assert!(!Manifest::exists(&d));
        m.write_atomic(&d).unwrap();
        assert!(Manifest::exists(&d));
        assert_eq!(Manifest::load(&d).unwrap(), m);
        // Corrupt manifests are clean errors.
        fs::write(d.join(MANIFEST_FILE), "{\"wal\":\"other\"}").unwrap();
        assert!(Manifest::load(&d).is_err());
        fs::write(d.join(MANIFEST_FILE), "{\"wal\":\"fast-wal-v1\",\"rows\":100,\"q\":8,\"shards\":8}")
            .unwrap();
        assert!(Manifest::load(&d).is_err(), "rows % shards != 0 must be rejected");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn segments_list_in_lsn_order() {
        let d = tmpdir("list");
        fs::create_dir_all(shard_dir(&d, 0)).unwrap();
        for lsn in [7u64, 1, 300] {
            fs::write(segment_path(&d, 0, lsn), b"x").unwrap();
        }
        // Debris is ignored.
        fs::write(shard_dir(&d, 0).join("seg-zzz.wal"), b"x").unwrap();
        fs::write(shard_dir(&d, 0).join("other.tmp"), b"x").unwrap();
        let segs = list_segments(&d, 0).unwrap();
        assert_eq!(segs.iter().map(|s| s.first_lsn).collect::<Vec<_>>(), vec![1, 7, 300]);
        // A shard with no directory lists empty.
        assert!(list_segments(&d, 3).unwrap().is_empty());
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn dir_lock_excludes_a_second_acquirer_and_releases_on_drop() {
        let d = tmpdir("lock");
        let lock = DirLock::acquire(&d).unwrap();
        // Held: a second acquire (separate file handle, so a separate
        // OS lock owner) must fail.
        assert!(DirLock::acquire(&d).is_err());
        drop(lock);
        // Released on drop (the OS drops the flock with the handle —
        // the same mechanism that releases it on SIGKILL).
        let lock = DirLock::acquire(&d).unwrap();
        drop(lock);
        // Leftover lock-file debris is inert, never a stale lock.
        assert!(d.join(LOCK_FILE).exists());
        let lock = DirLock::acquire(&d).unwrap();
        drop(lock);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn segment_header_round_trips() {
        let h = encode_segment_header(5);
        let mut r = &h[..];
        assert_eq!(read_segment_header(&mut r, Path::new("t")).unwrap(), 5);
        let mut bad = h;
        bad[0] ^= 0xFF;
        let mut r = &bad[..];
        assert!(read_segment_header(&mut r, Path::new("t")).is_err());
        let mut r = &h[..4]; // truncated
        assert!(read_segment_header(&mut r, Path::new("t")).is_err());
    }
}
