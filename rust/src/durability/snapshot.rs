//! Full-state snapshots: the compaction anchor of the durability
//! subsystem. A snapshot pins the whole logical row state plus every
//! shard's `(commit_seq, lsn)` watermark; WAL segments whose records
//! are all at or below the watermark are garbage once the snapshot is
//! durable.
//!
//! ## File format (`snap-<id:016x>.fastsnap`)
//!
//! ```text
//! magic:8 ("FASTSNP1") | version:u32 | rows:u32 | q:u32 | shards:u32
//! | shards × (commit_seq:u64, lsn:u64)
//! | rows × state:u32
//! | digest:u64 (FNV-1a of the state, same fn as trace/serve DIGEST)
//! | crc:u32   (CRC32 of every preceding byte)
//! ```
//!
//! All integers little-endian. Snapshots are written atomically —
//! temp file, fsync, rename — so a crash mid-write leaves only `.tmp`
//! debris, never a half-snapshot under the real name. Loading verifies
//! magic, CRC *and* recomputes the digest, so a corrupt snapshot is
//! skipped (recovery falls back to the previous one plus a longer WAL
//! tail) rather than trusted.

use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context};

use crate::apps::trace::state_digest;
use crate::util::crc32::crc32;
use crate::Result;

pub const SNAPSHOT_MAGIC: &[u8; 8] = b"FASTSNP1";
pub const SNAPSHOT_VERSION: u32 = 1;

/// One shard's durability watermark at snapshot time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardMark {
    /// Last committed batch sequence number.
    pub commit_seq: u64,
    /// Last WAL log sequence number folded into the snapshot (covers
    /// writes too — `commit_seq` alone cannot order them).
    pub lsn: u64,
}

/// A decoded snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Snapshot {
    pub rows: usize,
    pub q: usize,
    pub shards: usize,
    pub per_shard: Vec<ShardMark>,
    /// Logical row state (row-indexed across all shards).
    pub state: Vec<u32>,
}

impl Snapshot {
    /// FNV-1a digest of the state (the serve/trace digest function).
    pub fn digest(&self) -> u64 {
        state_digest(&self.state)
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut buf =
            Vec::with_capacity(24 + self.per_shard.len() * 16 + self.state.len() * 4 + 12);
        buf.extend_from_slice(SNAPSHOT_MAGIC);
        buf.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.rows as u32).to_le_bytes());
        buf.extend_from_slice(&(self.q as u32).to_le_bytes());
        buf.extend_from_slice(&(self.shards as u32).to_le_bytes());
        for m in &self.per_shard {
            buf.extend_from_slice(&m.commit_seq.to_le_bytes());
            buf.extend_from_slice(&m.lsn.to_le_bytes());
        }
        for &w in &self.state {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        buf.extend_from_slice(&self.digest().to_le_bytes());
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
        ensure!(bytes.len() >= 24 + 12, "snapshot too short ({} bytes)", bytes.len());
        ensure!(&bytes[..8] == SNAPSHOT_MAGIC, "not a FAST snapshot (bad magic)");
        let u32_at = |at: usize| u32::from_le_bytes(bytes[at..at + 4].try_into().expect("4"));
        let u64_at = |at: usize| u64::from_le_bytes(bytes[at..at + 8].try_into().expect("8"));
        let version = u32_at(8);
        ensure!(
            version == SNAPSHOT_VERSION,
            "unsupported snapshot version {version} (this build speaks {SNAPSHOT_VERSION})"
        );
        let rows = u32_at(12) as usize;
        let q = u32_at(16) as usize;
        let shards = u32_at(20) as usize;
        ensure!(rows >= 1 && (1..=32).contains(&q), "snapshot shape {rows}x{q} implausible");
        ensure!(
            shards >= 1 && shards.is_power_of_two() && rows % shards == 0,
            "snapshot shards {shards} implausible for {rows} rows"
        );
        let want = 24 + shards * 16 + rows * 4 + 12;
        ensure!(
            bytes.len() == want,
            "snapshot length {} != shape-implied {want}",
            bytes.len()
        );
        let crc_stored = u32_at(bytes.len() - 4);
        ensure!(crc32(&bytes[..bytes.len() - 4]) == crc_stored, "snapshot CRC mismatch");
        let mut per_shard = Vec::with_capacity(shards);
        for s in 0..shards {
            per_shard.push(ShardMark {
                commit_seq: u64_at(24 + s * 16),
                lsn: u64_at(24 + s * 16 + 8),
            });
        }
        let state_at = 24 + shards * 16;
        let state: Vec<u32> = (0..rows).map(|r| u32_at(state_at + r * 4)).collect();
        let digest_stored = u64_at(bytes.len() - 12);
        let snap = Snapshot { rows, q, shards, per_shard, state };
        ensure!(
            snap.digest() == digest_stored,
            "snapshot digest mismatch (stored {digest_stored:016x}, state folds to {:016x})",
            snap.digest()
        );
        Ok(snap)
    }

    /// Write the snapshot atomically into `dir` under the next free
    /// id. Returns the final path.
    pub fn write_atomic(&self, dir: &Path) -> Result<PathBuf> {
        let id = list_snapshots(dir)?.last().map(|&(id, _)| id + 1).unwrap_or(1);
        let fin = dir.join(format!("snap-{id:016x}.fastsnap"));
        let tmp = dir.join(format!("snap-{id:016x}.fastsnap.tmp"));
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&self.encode())?;
            f.sync_data().context("fsyncing snapshot")?;
        }
        fs::rename(&tmp, &fin)
            .with_context(|| format!("renaming {} into place", fin.display()))?;
        // Make the rename itself durable where the platform allows.
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(fin)
    }
}

/// All snapshot files in `dir`, sorted ascending by id. Only the name
/// pattern is checked here — decode (and its CRC/digest verification)
/// happens on load.
pub fn list_snapshots(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    if !dir.is_dir() {
        return Ok(out);
    }
    for entry in fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(hex) = name.strip_prefix("snap-").and_then(|s| s.strip_suffix(".fastsnap"))
        else {
            continue;
        };
        if let Ok(id) = u64::from_str_radix(hex, 16) {
            out.push((id, entry.path()));
        }
    }
    out.sort_by_key(|&(id, _)| id);
    Ok(out)
}

/// Load the newest snapshot that decodes and verifies. Corrupt or
/// torn snapshot files are skipped (recovery prefers an older valid
/// snapshot plus a longer WAL tail over trusting damaged state);
/// `None` if no valid snapshot exists.
pub fn load_newest(dir: &Path) -> Result<Option<(PathBuf, Snapshot)>> {
    let mut snaps = list_snapshots(dir)?;
    snaps.reverse();
    for (_, path) in snaps {
        let bytes = fs::read(&path)
            .with_context(|| format!("reading snapshot {}", path.display()))?;
        match Snapshot::decode(&bytes) {
            Ok(snap) => return Ok(Some((path, snap))),
            Err(_) => continue, // skip damaged snapshots
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let d = std::env::temp_dir()
            .join(format!("fast-snap-{tag}-{}-{nanos}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn demo() -> Snapshot {
        Snapshot {
            rows: 8,
            q: 8,
            shards: 2,
            per_shard: vec![
                ShardMark { commit_seq: 3, lsn: 5 },
                ShardMark { commit_seq: 1, lsn: 1 },
            ],
            state: vec![1, 2, 3, 4, 5, 6, 7, 255],
        }
    }

    #[test]
    fn encode_decode_round_trips() {
        let s = demo();
        assert_eq!(Snapshot::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn corruption_is_detected() {
        let s = demo();
        let good = s.encode();
        for at in [0usize, 9, 30, good.len() - 5, good.len() - 1] {
            let mut bad = good.clone();
            bad[at] ^= 0x40;
            assert!(Snapshot::decode(&bad).is_err(), "flip at {at} must be caught");
        }
        assert!(Snapshot::decode(&good[..good.len() - 3]).is_err(), "truncation");
    }

    #[test]
    fn atomic_write_and_newest_selection() {
        let d = tmpdir("atomic");
        let a = demo();
        let mut b = demo();
        b.state[0] = 99;
        b.per_shard[0].lsn = 9;
        let pa = a.write_atomic(&d).unwrap();
        let pb = b.write_atomic(&d).unwrap();
        assert_ne!(pa, pb);
        let (path, newest) = load_newest(&d).unwrap().unwrap();
        assert_eq!(path, pb);
        assert_eq!(newest, b);
        // Corrupting the newest falls back to the older one.
        let mut bytes = fs::read(&pb).unwrap();
        let len = bytes.len();
        bytes[len - 2] ^= 0xFF;
        fs::write(&pb, bytes).unwrap();
        let (path, fallback) = load_newest(&d).unwrap().unwrap();
        assert_eq!(path, pa);
        assert_eq!(fallback, a);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn empty_dir_has_no_snapshot() {
        let d = tmpdir("empty");
        assert!(load_newest(&d).unwrap().is_none());
        let _ = fs::remove_dir_all(&d);
    }
}
