//! Minimal offline-vendored subset of the `anyhow` error-handling API.
//!
//! The real `anyhow` crate is not in the offline vendor set (DESIGN.md
//! §7: builds must succeed with no network and no registry cache), so
//! this crate implements exactly the surface the workspace uses, with
//! the same semantics:
//!
//! - [`Error`]: a boxed, type-erased error with a source chain.
//! - [`Result`]: `Result<T, Error>` with a defaultable error type.
//! - [`anyhow!`], [`bail!`], [`ensure!`]: ad-hoc error construction.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, pushing a message onto the source chain.
//! - `{:#}` display renders the whole chain joined by `": "` (matching
//!   anyhow), `{:?}` renders the chain as a "Caused by" list.
//!
//! Dropping the real crate back in is a one-line Cargo.toml change; no
//! call site references anything beyond the shared API.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with a defaultable error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error with an optional chain of sources.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap a concrete error.
    pub fn new<E>(err: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { inner: Box::new(err) }
    }

    /// Build an error from a display-able message.
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + Send + Sync + 'static,
    {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Push a context message onto the chain; the previous error
    /// becomes this one's `source()`.
    pub fn context<C>(self, context: C) -> Error
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(ContextError { msg: context.to_string(), source: self.inner }),
        }
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        // Auto-trait removal coercion: dyn Error + Send + Sync → dyn Error.
        let head: &(dyn StdError + 'static) = self.inner.as_ref();
        Chain { next: Some(head) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is never empty")
    }
}

/// Iterator over an [`Error`]'s source chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            let mut source = self.inner.source();
            while let Some(s) = source {
                write!(f, ": {s}")?;
                source = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`
// (same as real anyhow) — that is what makes this blanket From sound.
impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// A plain message posing as an error (no source).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context message chained in front of an underlying error.
struct ContextError {
    msg: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        let src: &(dyn StdError + 'static) = self.source.as_ref();
        Some(src)
    }
}

/// Attach context to failures, turning any error into [`Error`].
pub trait Context<T, E> {
    /// Wrap the error with a fixed message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-built message (only evaluated on
    /// the failure path).
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

// Sound alongside the blanket impl because `Error` itself does not
// implement `StdError` — the two impls cannot overlap.
impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (inline captures work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = anyhow!("top level {}", 7);
        assert_eq!(format!("{e}"), "top level 7");
        assert_eq!(format!("{e:#}"), "top level 7");
    }

    #[test]
    fn context_chains_in_alternate_display() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
        let e2 = e.context("loading artifacts");
        assert_eq!(
            format!("{e2:#}"),
            "loading artifacts: reading manifest: file missing"
        );
        assert_eq!(e2.chain().count(), 3);
        assert_eq!(e2.root_cause().to_string(), "file missing");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let r2: Result<()> = Err(anyhow!("deep"));
        let e2 = r2.with_context(|| format!("layer {}", 1)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "layer 1: deep");
    }

    #[test]
    fn with_context_is_lazy_on_success() {
        let r: Result<u32, std::io::Error> = Ok(5);
        let v = r.with_context(|| panic!("must not evaluate")).unwrap();
        assert_eq!(v, 5);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{:#}", f().unwrap_err()), "file missing");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(format!("{:#}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{:#}", f(3).unwrap_err()).contains("three"));
    }
}
