//! Database delta-update scenario (paper Section I: "the table update
//! in a database").
//!
//! Run: `cargo run --release --example database_delta`
//!
//! A key→counter table absorbs a skewed stream of 100k increments /
//! decrements through the coordinator. The batcher coalesces same-key
//! deltas and packs distinct keys into fully-concurrent batch ops; the
//! report shows how few macro batches the whole stream needed.

use fast_sram::apps::DeltaTable;
use fast_sram::coordinator::{EngineConfig, FastBackend, UpdateEngine};
use fast_sram::util::rng::Rng;

fn main() -> fast_sram::Result<()> {
    let rows = 1024; // 8 stacked macros
    let cfg = EngineConfig::new(rows, 16);
    let engine = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })?;
    let mut table = DeltaTable::new(engine);

    // Skewed workload: 80% of traffic hits 64 hot keys of 1000.
    let mut rng = Rng::new(2025);
    let n = 100_000;
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let key = if rng.chance(0.8) {
            rng.below(64)
        } else {
            64 + rng.below(936)
        };
        let delta = 1 + rng.below(9) as u32;
        if rng.chance(0.25) {
            table.decrement(key, delta)?;
        } else {
            table.increment(key, delta)?;
        }
    }
    let hot = table.get(0)?;
    let wall = t0.elapsed();

    let s = table.stats();
    println!("database delta-update: {n} updates over {} keys", table.len());
    println!("  hot key 0 final value : {hot}");
    println!("  batches flushed       : {}", s.batches);
    println!("  rows per batch        : {:.1}", s.rows_per_batch);
    println!(
        "  coalescing            : {:.1} requests per touched row",
        s.completed as f64 / s.rows_updated.max(1) as f64
    );
    println!("  modeled macro time    : {:.2} µs", s.modeled_ns / 1000.0);
    println!("  modeled energy        : {:.2} nJ", s.modeled_energy_pj / 1000.0);
    println!(
        "  wall time             : {:.1} ms ({:.2} M updates/s)",
        wall.as_secs_f64() * 1e3,
        n as f64 / wall.as_secs_f64() / 1e6
    );
    println!(
        "\n  vs row-by-row baseline: each update would need a read+write\n  \
         sweep — {n} serialized accesses instead of {} concurrent batches.",
        s.batches
    );
    table.close()?;
    Ok(())
}
