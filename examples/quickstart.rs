//! Quickstart: the FAST array in five minutes.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Shows the paper's core idea end-to-end: a q-bit add with write-back
//! to EVERY row of the array in q shift cycles — latency independent
//! of the row count — and how that compares against the conventional
//! row-by-row digital baseline.

use fast_sram::baseline::DigitalEngine;
use fast_sram::energy::{DigitalModel, FastModel};
use fast_sram::fastmem::{AluOp, FastArray};

fn main() {
    // The paper's showcase macro: 128 rows x 16 columns.
    let mut array = FastArray::new(128, 16);

    // Load a table: row r holds r*100.
    let init: Vec<u32> = (0..128).map(|r| (r * 100) as u32 & 0xFFFF).collect();
    array.load(&init);

    // One fully-concurrent batch op: every row adds its own delta,
    // in 16 shift cycles total (Fig. 1b).
    let deltas: Vec<u32> = (0..128).map(|r| (r + 1) as u32).collect();
    let report = array.batch_add(&deltas);
    println!(
        "batch add: {} rows updated concurrently in {} shift cycles",
        report.rows_active, report.cycles
    );
    assert_eq!(array.read_row(10), 1000 + 11);

    // Subtract and logic ops ride the same datapath (Section III.E)...
    array.batch_sub(&deltas);
    // peek_rows: verification read that does not touch the modeled
    // conventional port (snapshot would count 128 port reads).
    assert_eq!(array.peek_rows(), init);
    array.batch_logic(AluOp::Xor, &vec![0xFFFF; 128]);
    assert_eq!(array.read_row(0), !init[0] & 0xFFFF);
    array.batch_logic(AluOp::Xor, &vec![0xFFFF; 128]); // undo

    // ...and so does the paper's future-work integer multiply
    // (shift-and-add: q+1 batch ops, still fully row-parallel).
    let mul_report = array.batch_mul(&vec![3; 128]).unwrap();
    assert_eq!(array.read_row(10), (init[10] * 3) & 0xFFFF);
    println!(
        "batch mul x3: all rows in {} shift cycles (q·(q+1) bit-serial)",
        mul_report.cycles
    );
    array.load(&init);

    // The conventional near-memory baseline computes the same thing...
    let mut baseline = DigitalEngine::new(128, 16);
    baseline.load(&init);
    let sweep = baseline.batch_add(&deltas);

    // ...but costs R serialized accesses instead of q cycles:
    let fast_cost = FastModel::default().batch_op(128, 16);
    let dig_cost = DigitalModel::default().batch_update(128, 16);
    println!("\nmodeled whole-array update (128 rows, 16-bit):");
    println!(
        "  FAST    : {:>7.2} ns, {:>7.2} pJ",
        fast_cost.latency_ns,
        fast_cost.energy_fj / 1000.0
    );
    println!(
        "  digital : {:>7.2} ns, {:>7.2} pJ   ({} port accesses)",
        dig_cost.latency_ns,
        dig_cost.energy_fj / 1000.0,
        sweep.reads + sweep.writes
    );
    println!(
        "  -> {:.1}x faster, {:.1}x less energy (paper: 27.2x / 5.5x)",
        dig_cost.latency_ns / fast_cost.latency_ns,
        dig_cost.energy_fj / fast_cost.energy_fj
    );
}
