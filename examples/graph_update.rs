//! Graph feature-update scenario (paper Section I: "the parallel
//! feature update in graph computing", refs [7][8]).
//!
//! Run: `cargo run --release --example graph_update`
//!
//! Integer feature propagation on a random graph: every round, each
//! node sends an attenuated copy of its feature to its out-neighbours.
//! Through the coordinator a whole round collapses into a handful of
//! fully-concurrent FAST batches; the same run on the digital baseline
//! shows the modeled cost gap.

use fast_sram::apps::{reference_round, CsrGraph, GraphEngine};
use fast_sram::coordinator::{DigitalBackend, EngineConfig, FastBackend, UpdateEngine};

fn run(
    label: &str,
    graph: CsrGraph,
    feats: &[u32],
    fast: bool,
) -> fast_sram::Result<(Vec<u32>, f64, f64)> {
    let rows = 1024;
    let cfg = EngineConfig::new(rows, 16);
    let engine = if fast {
        UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })?
    } else {
        UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(DigitalBackend::new(plan.rows, plan.q)))
        })?
    };
    let mut ge = GraphEngine::new(graph, engine)?;
    ge.set_features(feats)?;
    ge.run(5, 2)?; // 5 rounds, attenuation f >> 2
    let out = ge.features()?;
    let s = ge.stats();
    println!(
        "{label:<18} batches={:<5} rows/batch={:<7.1} macro time={:>9.2} µs  energy={:>8.2} nJ",
        s.batches,
        s.rows_per_batch,
        s.modeled_ns / 1000.0,
        s.modeled_energy_pj / 1000.0
    );
    let (ns, pj) = (s.modeled_ns, s.modeled_energy_pj);
    ge.close()?;
    Ok((out, ns, pj))
}

fn main() -> fast_sram::Result<()> {
    let nodes = 1000;
    let graph = CsrGraph::random(nodes, 6, 42);
    println!(
        "graph: {} nodes, {} edges, 5 propagation rounds\n",
        graph.nodes(),
        graph.edges()
    );
    let feats: Vec<u32> = (0..nodes).map(|i| ((i * 97 + 13) % 50_000) as u32 & 0xFFFF).collect();

    let (fast_out, fast_ns, fast_pj) = run("FAST backend", graph.clone(), &feats, true)?;
    let (dig_out, dig_ns, dig_pj) = run("digital baseline", graph.clone(), &feats, false)?;

    assert_eq!(fast_out, dig_out, "backends must agree bit-for-bit");

    // Cross-check against the pure reference implementation.
    let mut want = feats.clone();
    for _ in 0..5 {
        want = reference_round(&graph, &want, 16, |f| f >> 2);
    }
    assert_eq!(fast_out, want, "engine must match the reference");

    println!(
        "\nresults identical; modeled speedup {:.1}x, energy saving {:.1}x",
        dig_ns / fast_ns,
        dig_pj / fast_pj
    );
    Ok(())
}
