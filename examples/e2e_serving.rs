//! END-TO-END driver: the full three-layer stack on a realistic mixed
//! workload.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`
//!
//! Layer 1 (Pallas bit-serial kernel) and Layer 2 (JAX batch-update
//! model) were AOT-lowered to `artifacts/*.hlo.txt` at build time; this
//! binary is pure Rust — Layer 3 loads the artifacts via PJRT and
//! serves a mixed database+graph workload through the concurrent
//! update engine, with the phase-accurate behavioural backend running
//! shadow validation of every result. Reported in EXPERIMENTS.md §E2E.

use std::time::{Duration, Instant};

use fast_sram::apps::CsrGraph;
use fast_sram::coordinator::{
    EngineConfig, FastBackend, UpdateEngine, UpdateRequest, XlaBackend,
};
use fast_sram::metrics::render_table;
use fast_sram::util::rng::Rng;

fn main() -> fast_sram::Result<()> {
    let rows = 1024;
    let q = 16;

    // --- Layer 3 engine on the Layer-1/2 XLA artifacts -------------------
    let mut cfg = EngineConfig::new(rows, q);
    cfg.seal_deadline = Duration::from_micros(150);
    cfg.queue_cap = 16_384;
    let engine = UpdateEngine::start(cfg.clone(), move |plan| {
        Ok(Box::new(XlaBackend::new("artifacts", plan.rows, plan.q)?))
    })?;
    // Shadow engine on the behavioural model for end-to-end validation.
    let shadow = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })?;

    println!("e2e: XLA-backed engine up ({} rows x {q} bits, backend {})", rows, engine.stats().backend);

    // --- mixed workload ---------------------------------------------------
    // Phase A: database-style skewed counter deltas.
    let mut rng = Rng::new(7);
    let n_db = 60_000;
    let t0 = Instant::now();
    for _ in 0..n_db {
        let row = if rng.chance(0.8) {
            rng.below(128) as usize
        } else {
            rng.below(rows as u64) as usize
        };
        let v = 1 + rng.below(999) as u32;
        let req = if rng.chance(0.25) {
            UpdateRequest::sub(row, v)
        } else {
            UpdateRequest::add(row, v)
        };
        engine.submit_blocking(req)?;
        shadow.submit_blocking(req)?;
    }
    let db_wall = t0.elapsed();

    // Phase B: graph feature propagation (messages through the batcher).
    let graph = CsrGraph::random(1000, 6, 99);
    let t1 = Instant::now();
    let mut n_graph = 0u64;
    for _round in 0..4 {
        let snap = engine.snapshot()?;
        for n in 0..graph.nodes() {
            let m = (snap[n] >> 3) & 0xFFFF;
            if m == 0 {
                continue;
            }
            for &t in graph.out_neighbors(n) {
                let req = UpdateRequest::add(t, m);
                engine.submit_blocking(req)?;
                shadow.submit_blocking(req)?;
                n_graph += 1;
            }
        }
        // Commit the round: per-shard drains (single-shard engines
        // here, so one drain each — no whole-engine flush anymore).
        engine.drain_shard(0)?;
        shadow.drain_shard(0)?;
    }
    let graph_wall = t1.elapsed();

    // --- validation: XLA path == behavioural path bit-for-bit ------------
    let got = engine.snapshot()?;
    let want = shadow.snapshot()?;
    assert_eq!(got, want, "XLA and behavioural stacks diverged");
    println!("validation: XLA state == behavioural state over {} rows ✓", rows);

    // --- report -----------------------------------------------------------
    let s = engine.stats();
    let total_updates = n_db as u64 + n_graph;
    let total_wall = db_wall + graph_wall;
    let rows_txt = vec![
        ("backend".into(), s.backend.to_string()),
        ("total updates".into(), format!("{total_updates}")),
        ("  database phase".into(), format!("{n_db} ({:.1} ms)", db_wall.as_secs_f64() * 1e3)),
        ("  graph phase".into(), format!("{n_graph} ({:.1} ms)", graph_wall.as_secs_f64() * 1e3)),
        ("batches".into(), format!("{}", s.batches)),
        ("rows/batch".into(), format!("{:.1}", s.rows_per_batch)),
        (
            "coalescing".into(),
            format!("{:.1} req per touched row", s.completed as f64 / s.rows_updated.max(1) as f64),
        ),
        ("modeled macro time".into(), format!("{:.2} µs", s.modeled_ns / 1000.0)),
        ("modeled energy".into(), format!("{:.2} nJ", s.modeled_energy_pj / 1000.0)),
        (
            "throughput".into(),
            format!(
                "{:.2} M updates/s wall",
                total_updates as f64 / total_wall.as_secs_f64() / 1e6
            ),
        ),
        ("apply p50 / p99".into(), format!("{} / {} ns", s.apply_wall.p50_ns, s.apply_wall.p99_ns)),
    ];
    print!("{}", render_table("e2e serving", &rows_txt));

    // Modeled comparison against the row-by-row baseline at equal work:
    let dig = fast_sram::energy::DigitalModel::default();
    let per_batch_dig = dig.batch_update(rows, q);
    let dig_ns = per_batch_dig.latency_ns * s.batches as f64;
    println!(
        "same batches on the digital baseline: {:.2} µs -> modeled speedup {:.1}x",
        dig_ns / 1000.0,
        dig_ns / s.modeled_ns
    );

    engine.shutdown()?;
    shadow.shutdown()?;
    Ok(())
}
