//! Transient waveform demo (paper Figs. 7 and 8).
//!
//! Run: `cargo run --release --example waveforms`
//!
//! Drives the RC-level cell-chain simulator through shift and add
//! operations at the 800 MHz silicon operating point and renders the
//! node waveforms as ASCII oscillograms (CSV files are written to
//! ./results for real plotting).

use fast_sram::experiments::waveforms;

fn main() -> fast_sram::Result<()> {
    let period = 1.25; // ns, = 800 MHz @ 1.0 V

    let f7 = waveforms::run_fig7(period);
    print!("{}", waveforms::render_fig7(&f7, 72));
    println!();
    let f8 = waveforms::run_fig8(period, 0b0101, 0b0110);
    print!("{}", waveforms::render_fig8(&f8, 72));

    std::fs::create_dir_all("results")?;
    std::fs::write("results/fig7_shift.csv", f7.set.to_csv())?;
    std::fs::write("results/fig8_add.csv", f8.set.to_csv())?;
    println!("\nfull traces: results/fig7_shift.csv, results/fig8_add.csv");
    Ok(())
}
